//! Priority-scheduling ablation: interactive-class latency under a
//! batch-prompt flood, FIFO vs priority ordering vs priority plus
//! preemption (mid-prefill pause + decode-slot eviction).
//!
//! Workload: `N_BATCH` batch-class requests with long prompts are
//! submitted at t0 (the flood), then `N_INT` short interactive
//! requests arrive spaced through the run.  Reported per policy:
//! wall time, aggregate decode tok/s, interactive TTFT p50/p99, batch
//! TTFT p50, and the preemption counters.  FIFO head-of-line-blocks
//! every interactive arrival behind the whole flood's prefill work;
//! priority ordering lets them jump the admission queue; preemption
//! additionally pauses an in-flight batch prefill and — once the
//! decode slots fill — evicts a decoding batch sequence (KV
//! checkpointed to the prefix cache, resumed via chunked catch-up).
//!
//! Scheduling must never change tokens: all three policies are
//! asserted to produce identical greedy streams per request id.
//!
//! `BENCH_SMOKE=1` runs a reduced configuration (CI lane);
//! `BENCH_JSON_OUT=dir` writes the table as a JSON artifact.

use std::collections::HashMap;
use std::sync::mpsc::Receiver;
use std::time::Instant;

use umserve::bench_harness::{banner, fmt_f, maybe_write_json, smoke_scale, synth_prompt, Table};
use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::{
    EngineConfig, Event, GenRequest, KvConfig, Priority, PromptInput, SchedConfig,
};
use umserve::engine::sampler::SamplingParams;

/// Interactive arrivals: one every `INT_EVERY` ticks from `INT_START`.
const INT_START: usize = 8;
const INT_EVERY: usize = 6;

fn main() -> anyhow::Result<()> {
    banner("Priority ablation — interactive TTFT under a batch-prompt flood");

    let n_batch = smoke_scale(16, 6);
    let n_int = smoke_scale(8, 4);
    let gen_batch = smoke_scale(40, 12);
    let gen_int = 8;
    let batch_prompt = 192;
    let int_prompt = 24;

    let mut table = Table::new(
        &format!(
            "Priority scheduling (qwen3-0.6b-sim, {n_batch} batch x {batch_prompt}-tok prompts \
             flood, {n_int} interactive x {int_prompt}-tok arrivals)"
        ),
        &[
            "Policy",
            "Wall (s)",
            "Agg tok/s",
            "Int TTFT p50 (ms)",
            "Int TTFT p99 (ms)",
            "Batch TTFT p50 (ms)",
            "Preempt",
            "Evict",
            "Resume",
        ],
    );

    // policy -> per-request greedy token streams (keyed by request id).
    let mut outputs: HashMap<&'static str, HashMap<u64, Vec<i32>>> = HashMap::new();

    for (label, psched, preempt) in [
        ("fifo", false, false),
        ("priority", true, false),
        ("priority+preemption", true, true),
    ] {
        let mut s = Scheduler::new(EngineConfig {
            model: "qwen3-0.6b".into(),
            artifacts_dir: "artifacts".into(),
            warmup: false,
            sched: SchedConfig {
                prefill_chunk_tokens: 32,
                prefill_chunks_per_step: 1,
                priority_sched: psched,
                preemption: preempt,
                // Aging off: the ablation isolates ordering + preemption
                // (starvation freedom is covered by tests/test_priority.rs).
                aging_ticks: 0,
                ..Default::default()
            },
            kv: KvConfig {
                text_cache_bytes: 64 << 20,
                cache_finished: false,
                allow_shrink: false,
                ..Default::default()
            },
            ..Default::default()
        })?;
        // Warm executables before timing.
        for i in 0..4u64 {
            let _ = submit(&mut s, 900 + i, 8, 4, Priority::Normal);
        }
        s.run_until_idle();

        let t0 = Instant::now();
        let mut rxs: Vec<(u64, Priority, Receiver<Event>)> = Vec::new();
        for i in 0..n_batch {
            let rx = submit(&mut s, 1000 + i as u64, batch_prompt, gen_batch, Priority::Batch);
            rxs.push((1000 + i as u64, Priority::Batch, rx));
        }
        let mut next_int = 0usize;
        let mut ticks = 0usize;
        while next_int < n_int
            || s.active_count() + s.queued_count() + s.evicted_count() > 0
        {
            if next_int < n_int && ticks >= INT_START + next_int * INT_EVERY {
                let id = 2000 + next_int as u64;
                let rx = submit(&mut s, id, int_prompt, gen_int, Priority::Interactive);
                rxs.push((id, Priority::Interactive, rx));
                next_int += 1;
            }
            s.tick();
            ticks += 1;
        }
        let wall = t0.elapsed().as_secs_f64();

        let mut int_ttfts: Vec<f64> = Vec::new();
        let mut batch_ttfts: Vec<f64> = Vec::new();
        let mut tokens_out = 0usize;
        let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
        for (id, class, rx) in &rxs {
            for ev in rx.try_iter() {
                match ev {
                    Event::Token { token, .. } if token >= 0 => {
                        streams.entry(*id).or_default().push(token);
                    }
                    Event::Done { usage, timing, .. } => {
                        tokens_out += usage.completion_tokens;
                        if *id >= 1000 {
                            match class {
                                Priority::Interactive => int_ttfts.push(timing.ttft_ms),
                                _ => batch_ttfts.push(timing.ttft_ms),
                            }
                        }
                    }
                    Event::Error { message, .. } => panic!("request {id} failed: {message}"),
                    _ => {}
                }
            }
        }
        int_ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        batch_ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(int_ttfts.len(), n_int, "missing interactive completions");

        table.row(vec![
            label.into(),
            fmt_f(wall, 2),
            fmt_f(tokens_out as f64 / wall, 1),
            fmt_f(pct(&int_ttfts, 0.50), 1),
            fmt_f(pct(&int_ttfts, 0.99), 1),
            fmt_f(pct(&batch_ttfts, 0.50), 1),
            s.metrics.counter("preemptions").to_string(),
            s.metrics.counter("evictions").to_string(),
            s.metrics.counter("evicted_resumes").to_string(),
        ]);
        eprintln!(
            "  {label}: wall {wall:.2}s, int p99 {:.1} ms, preempt {} / evict {} / resume {}",
            pct(&int_ttfts, 0.99),
            s.metrics.counter("preemptions"),
            s.metrics.counter("evictions"),
            s.metrics.counter("evicted_resumes"),
        );
        // Every eviction must eventually resume (nothing stranded).
        assert_eq!(
            s.metrics.counter("evictions"),
            s.metrics.counter("evicted_resumes"),
            "evicted sequences must all resume"
        );
        outputs.insert(label, streams);
    }

    // Scheduling policy must not change sampled tokens (greedy).
    let fifo = &outputs["fifo"];
    for policy in ["priority", "priority+preemption"] {
        let other = &outputs[policy];
        assert_eq!(fifo.len(), other.len(), "{policy}: request count mismatch");
        for (id, toks) in fifo {
            assert_eq!(
                toks, &other[id],
                "{policy}: request {id} diverged from FIFO output"
            );
        }
        println!("output equality vs fifo ({policy}): IDENTICAL");
    }

    table.print();
    maybe_write_json("ablation_priority", &[&table])?;
    println!("expected: priority ordering collapses interactive TTFT p50/p99 vs");
    println!("FIFO (no head-of-line blocking behind the flood's prefill), and");
    println!("preemption bounds the tail under decode-slot pressure, with");
    println!("aggregate throughput within a few percent of FIFO.");
    Ok(())
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 * q) as usize).min(sorted.len() - 1)]
}

fn submit(
    s: &mut Scheduler,
    id: u64,
    prompt_len: usize,
    n_new: usize,
    priority: Priority,
) -> Receiver<Event> {
    let (tx, rx) = std::sync::mpsc::channel();
    s.submit(GenRequest {
        id,
        prompt: PromptInput::Tokens(synth_prompt(id, prompt_len, 2048)),
        params: SamplingParams { stop_on_eos: false, ..SamplingParams::greedy(n_new) },
        priority,
        events: tx,
        enqueued_at: Instant::now(),
    });
    rx
}
