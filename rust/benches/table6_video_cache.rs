//! Table 6: video cache effectiveness vs frame count (Qwen3-VL-4B-sim).
//!
//! Paper: 4 frames 2.4 s -> 0.18 s (13.3x, 86 MB) rising to 32 frames
//! 9.4 s -> 0.38 s (24.7x, 486 MB) — more frames: bigger cold cost,
//! bigger win, bigger cache entries.  The "Cold (batched)" column runs
//! the same cold request on a second engine with encoder batching on
//! (`vision_r224_b8`, 8 encode units/tick) — the cache win stacks on
//! top of a cheaper cold path.

mod mm_common;

use mm_common::run_request;
use umserve::bench_harness::{banner, maybe_write_json, smoke, smoke_scale, Table};
use umserve::cache::kv_one_bytes;
use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::{EngineConfig, KvConfig, PromptInput, VisionConfig};
use umserve::multimodal::image::ImageSource;
use umserve::multimodal::video::{generate_video, sample_frames};

fn main() -> anyhow::Result<()> {
    banner("Table 6 — video cache effectiveness vs frame count");
    let n_new = smoke_scale(8, 4);
    let frame_counts: &[usize] = if smoke() { &[4, 8] } else { &[4, 8, 16, 32] };

    let base_cfg = EngineConfig {
        model: "qwen3-vl-4b".into(),
        artifacts_dir: "artifacts".into(),
        warmup: false,
        kv: KvConfig { text_cache_bytes: 0, mm_emb_cache_bytes: 1 << 30, mm_kv_cache_bytes: 1 << 30, ..Default::default() },
        ..Default::default()
    };
    let mut s = Scheduler::new(base_cfg.clone())?;
    // A second engine with encoder batching on: its cold column shows
    // what batched `vision_r{res}_b{B}` dispatches shave off the
    // frame-encode bound (its caches are its own, so the bench clip is
    // cold there too).
    let mut sb = Scheduler::new(EngineConfig {
        vision: VisionConfig { encodes_per_step: 8, batch: 8, ..base_cfg.vision.clone() },
        ..base_cfg
    })?;
    // Warm every embed bucket with a different clip (compile time must
    // not pollute the cold column; caches stay cold for the bench clip).
    let warm_clip = generate_video(7, 10.0, 8.0, 224);
    for &n in frame_counts {
        let idx = sample_frames(&warm_clip, n);
        let warm = || PromptInput::Multimodal {
            images: idx
                .iter()
                .map(|&i| ImageSource::Bytes(warm_clip.frames[i].encode_raw()))
                .collect(),
            text: "warmup".into(),
        };
        let _ = run_request(&mut s, warm(), 2)?;
        let _ = run_request(&mut sb, warm(), 2)?;
    }

    let mut table = Table::new(
        "Table 6 — video cache vs frames (qwen3-vl-4b-sim, 10s clip)",
        &["Frames", "Cold", "Cold (batched)", "Cached", "Speedup", "Cache"],
    );
    for &n in frame_counts {
        // A DISTINCT clip per row: frames shared between rows would
        // pre-hit the embedding cache and shrink the cold column.
        let video = generate_video(606 + n as u64, 10.0, 8.0, 224);
        let idx = sample_frames(&video, n);
        let mk = || PromptInput::Multimodal {
            images: idx
                .iter()
                .map(|&i| ImageSource::Bytes(video.frames[i].encode_raw()))
                .collect(),
            text: format!("summarize using {n} frames"),
        };
        let (t_cold, _, cold) = run_request(&mut s, mk(), n_new)?;
        let (_, _, cold_b) = run_request(&mut sb, mk(), n_new)?;
        let (t_hot, _, cached) = run_request(&mut s, mk(), n_new)?;
        assert!(t_hot.kv_full_hit, "repeat video query must fully hit");
        let info = s.engine.rt.info.clone();
        let emb_bytes = n * 16 * info.d_model * 4;
        let cache_bytes = emb_bytes + kv_one_bytes(&info);
        table.row(vec![
            n.to_string(),
            format!("{cold:.2}s"),
            format!("{cold_b:.2}s"),
            format!("{cached:.3}s"),
            format!("{:.1}x", cold / cached),
            format!("{:.1} MB", cache_bytes as f64 / 1e6),
        ]);
        eprintln!(
            "  {n} frames: cold {cold:.2}s ({} encodes, {:.0} ms vision), cached {cached:.3}s",
            t_cold.vision_total - t_cold.vision_cached,
            t_cold.vision_ms
        );
    }
    table.print();
    maybe_write_json("table6_video_cache", &[&table])?;
    println!("paper shape check: cold cost and speedup grow with frame count.");
    Ok(())
}
