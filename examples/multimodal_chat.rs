//! Multimodal chat: a multi-turn conversation about one image,
//! demonstrating Algorithm 3's content-based prefix caching — the same
//! image arrives over three different transports (raw bytes, base64
//! data URL, file path) and still hits the cache every time.
//!
//! ```bash
//! make artifacts && cargo run --release --example multimodal_chat
//! ```

use std::time::Instant;

use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::{EngineConfig, Event, GenRequest, PromptInput};
use umserve::engine::sampler::SamplingParams;
use umserve::multimodal::image::{generate_image, ImageSource};

fn main() -> anyhow::Result<()> {
    let mut s = Scheduler::new(EngineConfig {
        model: "qwen3-vl-4b".into(),
        ..Default::default()
    })?;

    // A synthetic 448x448 "photo", shipped three different ways.
    let img = generate_image(12345, 448);
    let tmp = std::env::temp_dir().join("umserve_example.uimg");
    std::fs::write(&tmp, img.encode_rle())?;
    let transports: Vec<(&str, ImageSource)> = vec![
        ("raw bytes", ImageSource::Bytes(img.encode_raw())),
        ("base64 data URL", ImageSource::DataUrl(ImageSource::to_data_url(&img))),
        ("file path (RLE)", ImageSource::Path(tmp.to_string_lossy().into_owned())),
    ];
    let questions = [
        "describe this image",
        "what colors are dominant",
        "describe this image", // repeat of turn 1 -> full KV hit
    ];

    for (turn, ((transport, source), question)) in
        transports.into_iter().zip(questions).enumerate()
    {
        let (tx, rx) = std::sync::mpsc::channel();
        let t0 = Instant::now();
        s.submit(GenRequest {
            id: turn as u64 + 1,
            prompt: PromptInput::Multimodal { images: vec![source], text: question.into() },
            params: SamplingParams::greedy(16),
            priority: Default::default(),
            events: tx,
            enqueued_at: Instant::now(),
        });
        s.run_until_idle();
        let wall = t0.elapsed().as_secs_f64();
        let mut reply = String::new();
        for ev in rx.try_iter() {
            match ev {
                Event::Token { text, .. } => reply.push_str(&text),
                Event::Done { timing, .. } => {
                    println!(
                        "turn {} [{transport:>18}] {:>6.2}s  vision {}/{} cached, kv_hit={} ttft {:>6.0}ms",
                        turn + 1,
                        wall,
                        timing.vision_cached,
                        timing.vision_total,
                        timing.kv_full_hit,
                        timing.ttft_ms,
                    );
                    println!("  Q: {question}\n  A: {:?}", truncate(&reply, 60));
                }
                Event::Error { message, .. } => anyhow::bail!(message),
            }
        }
    }

    let snap = s.snapshot();
    println!(
        "\nmm cache: emb {}h/{}m, kv {}h/{}m — identical pixels hashed identically across all transports",
        snap.mm_cache.emb_hits, snap.mm_cache.emb_misses, snap.mm_cache.kv_hits, snap.mm_cache.kv_misses
    );
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    s.chars().take(n).collect()
}
