//! Video analysis: frame sampling, temporal pooling, and video-level
//! content caching (§4.2 / Tables 3 & 6 in miniature).
//!
//! ```bash
//! make artifacts && cargo run --release --example video_analysis
//! ```

use std::time::Instant;

use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::{EngineConfig, Event, GenRequest, PromptInput};
use umserve::engine::sampler::SamplingParams;
use umserve::multimodal::image::ImageSource;
use umserve::multimodal::video::{generate_video, sample_frames};

fn main() -> anyhow::Result<()> {
    let mut s = Scheduler::new(EngineConfig {
        model: "qwen3-vl-4b".into(),
        ..Default::default()
    })?;

    // A synthetic 10-second clip at 8 fps, 224px frames.
    let video = generate_video(777, 10.0, 8.0, 224);
    println!(
        "clip: {:.0}s @ {} fps = {} frames ({}px)",
        video.duration_secs(),
        video.fps,
        video.frames.len(),
        video.frames[0].width
    );

    for n_frames in [4usize, 16, 48] {
        let idx = sample_frames(&video, n_frames);
        let ask = |s: &mut Scheduler, q: &str, id: u64| -> anyhow::Result<(f64, bool)> {
            let (tx, rx) = std::sync::mpsc::channel();
            let t0 = Instant::now();
            s.submit(GenRequest {
                id,
                prompt: PromptInput::Multimodal {
                    images: idx
                        .iter()
                        .map(|&i| ImageSource::Bytes(video.frames[i].encode_raw()))
                        .collect(),
                    text: q.into(),
                },
                params: SamplingParams::greedy(12),
                priority: Default::default(),
                events: tx,
                enqueued_at: Instant::now(),
            });
            s.run_until_idle();
            let wall = t0.elapsed().as_secs_f64();
            let mut hit = false;
            for ev in rx.try_iter() {
                match ev {
                    Event::Done { timing, .. } => hit = timing.kv_full_hit,
                    Event::Error { message, .. } => anyhow::bail!(message),
                    _ => {}
                }
            }
            Ok((wall, hit))
        };

        let q = format!("summarize the motion using {n_frames} frames");
        let (cold, _) = ask(&mut s, &q, n_frames as u64 * 10)?;
        let (hot, hit) = ask(&mut s, &q, n_frames as u64 * 10 + 1)?;
        assert!(hit, "repeat video query must hit the KV cache");
        println!(
            "{n_frames:>3} frames: cold {cold:>6.2}s -> cached {hot:>6.3}s ({:>5.1}x speedup)",
            cold / hot
        );
    }

    let snap = s.snapshot();
    println!(
        "\nframe-embedding cache: {} hits / {} misses ({} MB); temporal pools: {}",
        snap.mm_cache.emb_hits,
        snap.mm_cache.emb_misses,
        snap.mm_cache.emb_bytes / (1 << 20),
        snap.metrics.counter("mm_temporal_pools"),
    );
    Ok(())
}
