//! Quickstart: load a model, generate text, show prefix-cache reuse.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::{EngineConfig, Event, GenRequest, PromptInput};
use umserve::engine::sampler::SamplingParams;

fn main() -> anyhow::Result<()> {
    // 1. Build a scheduler: loads weights onto the PJRT device, parses
    //    the AOT manifest, sets up caches.
    let mut s = Scheduler::new(EngineConfig {
        model: "qwen3-0.6b".into(),
        ..Default::default()
    })?;

    // 2. Generate (greedy, 32 tokens).  The scheduler is channel-based:
    //    tokens stream over `rx` as they are produced.
    let run = |s: &mut Scheduler, id: u64, prompt: &str| -> anyhow::Result<f64> {
        let (tx, rx) = std::sync::mpsc::channel();
        s.submit(GenRequest {
            id,
            prompt: PromptInput::Text(prompt.into()),
            params: SamplingParams::greedy(32),
            priority: Default::default(),
            events: tx,
            enqueued_at: std::time::Instant::now(),
        });
        s.run_until_idle();
        let mut out = String::new();
        let mut ttft = 0.0;
        for ev in rx.try_iter() {
            match ev {
                Event::Token { text, .. } => out.push_str(&text),
                Event::Done { timing, usage, .. } => {
                    ttft = timing.ttft_ms;
                    println!(
                        "prompt: {prompt:?}\ncompletion ({} tok, ttft {:.0} ms): {out:?}\n",
                        usage.completion_tokens, timing.ttft_ms
                    );
                }
                Event::Error { message, .. } => anyhow::bail!(message),
            }
        }
        Ok(ttft)
    };

    let prompt = "The quick brown fox jumps over the lazy dog. Continuous batching";
    let cold = run(&mut s, 1, prompt)?;

    // 3. Same prompt again: Algorithm 2 full prefix hit — prefill is
    //    skipped entirely, TTFT drops.
    let warm = run(&mut s, 2, prompt)?;
    println!("TTFT cold {cold:.0} ms -> cached {warm:.0} ms ({:.1}x)", cold / warm);

    // 4. Live engine/cache introspection.
    let snap = s.snapshot();
    let (hits, misses, _, bytes) = snap.text_cache;
    println!("text prefix cache: {hits} hits / {misses} misses, {bytes} bytes held");
    Ok(())
}
