//! END-TO-END SERVING DRIVER (the repo's full-system validation).
//!
//! Boots the real HTTP server (OpenAI-compatible API) on a local port,
//! then drives it the way an agent framework would (§4.4 "Enabling
//! Local AI Agents"): a swarm of concurrent HTTP clients, each holding
//! a role with a shared system prompt, issuing streamed and unstreamed
//! chat completions.  Reports per-request latency, aggregate token
//! throughput, request throughput, and cache statistics scraped from
//! /metrics — proving all layers compose: HTTP server -> scheduler ->
//! continuous batching engine -> PJRT artifacts compiled from the
//! JAX+Pallas stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example agent_swarm
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use umserve::coordinator::scheduler::Scheduler;
use umserve::coordinator::EngineConfig;
use umserve::substrate::json::{parse, Json};

const N_AGENTS: usize = 8;
const TURNS_PER_AGENT: usize = 3;
const MAX_TOKENS: usize = 24;

fn main() -> anyhow::Result<()> {
    // ---- boot the full server stack ----
    let handle = Scheduler::spawn(EngineConfig {
        model: "qwen3-0.6b".into(),
        ..Default::default()
    })?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let handle = handle.clone();
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let _ = umserve::server::serve(
                listener,
                handle.into(),
                "qwen3-0.6b".into(),
                umserve::coordinator::Priority::Normal,
                shutdown,
            );
        });
    }
    println!("server up at http://{addr} — launching {N_AGENTS} agents x {TURNS_PER_AGENT} turns");

    // ---- the swarm ----
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for agent in 0..N_AGENTS {
        joins.push(std::thread::spawn(move || agent_loop(addr, agent)));
    }
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    for j in joins {
        let (lat, tok) = j.join().expect("agent panicked").expect("agent failed");
        latencies.extend(lat);
        tokens += tok;
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    let total_reqs = N_AGENTS * TURNS_PER_AGENT;
    println!("\n==== agent swarm report ====");
    println!("requests: {total_reqs} over {wall:.2}s = {:.2} req/s", total_reqs as f64 / wall);
    println!("tokens:   {tokens} = {:.1} tok/s aggregate", tokens as f64 / wall);
    println!(
        "latency:  p50 {:.0} ms | p95 {:.0} ms | max {:.0} ms",
        latencies[n / 2] * 1e3,
        latencies[((n as f64 * 0.95) as usize).min(n - 1)] * 1e3,
        latencies[n - 1] * 1e3
    );

    // ---- scrape /metrics from the live server ----
    let metrics = http_get(addr, "/metrics")?;
    for key in [
        "umserve_requests_completed",
        "umserve_tokens_generated",
        "umserve_text_cache_hits",
        "umserve_occupancy_mean",
    ] {
        if let Some(line) = metrics.lines().find(|l| l.starts_with(key)) {
            println!("metrics:  {line}");
        }
    }
    shutdown.store(true, std::sync::atomic::Ordering::Relaxed);
    handle.shutdown();
    assert_eq!(
        latencies.len(),
        total_reqs,
        "every request must complete"
    );
    println!("\nE2E OK: HTTP -> scheduler -> batched engine -> PJRT artifacts.");
    Ok(())
}

/// One agent: a role-specific system prompt (shared across its turns —
/// exercising the text prefix cache) and a few chat turns.
fn agent_loop(addr: std::net::SocketAddr, agent: usize) -> anyhow::Result<(Vec<f64>, usize)> {
    let roles = ["planner", "researcher", "critic", "summarizer"];
    let role = roles[agent % roles.len()];
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    for turn in 0..TURNS_PER_AGENT {
        let body = Json::obj(vec![
            ("model", Json::str("qwen3-0.6b")),
            ("max_tokens", Json::num(MAX_TOKENS as f64)),
            (
                "messages",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("role", Json::str("system")),
                        (
                            "content",
                            Json::str(format!(
                                "You are the {role} agent in a local multi-agent swarm. Be concise."
                            )),
                        ),
                    ]),
                    Json::obj(vec![
                        ("role", Json::str("user")),
                        ("content", Json::str(format!("agent {agent} turn {turn}: proceed"))),
                    ]),
                ]),
            ),
        ]);
        let t = Instant::now();
        let resp = http_post_json(addr, "/v1/chat/completions", &body.to_string())?;
        latencies.push(t.elapsed().as_secs_f64());
        let v = parse(&resp).map_err(|e| anyhow::anyhow!("bad response json: {e}\n{resp}"))?;
        let completion = v
            .path(&["usage", "completion_tokens"])
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing usage in {resp}"))?;
        anyhow::ensure!(completion > 0, "empty completion");
        tokens += completion;
    }
    Ok((latencies, tokens))
}

// ---- tiny HTTP client (std only) ----

fn http_post_json(addr: std::net::SocketAddr, path: &str, body: &str) -> anyhow::Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    write!(
        conn,
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    read_response(conn)
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> anyhow::Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    write!(conn, "GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n")?;
    read_response(conn)
}

fn read_response(conn: TcpStream) -> anyhow::Result<String> {
    let mut r = BufReader::new(conn);
    let mut status = String::new();
    r.read_line(&mut status)?;
    anyhow::ensure!(status.contains("200"), "HTTP error: {status}");
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        r.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse()?;
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(String::from_utf8(body)?)
}
