"""L2: the decoder-only transformer compute graph (text path).

Every function here is pure jax, calls the L1 Pallas kernels for its
GEMM/attention hot-spots, and is AOT-lowered by ``aot.py`` into one HLO
artifact per (function, bucket).  Weights arrive as a flat tuple in
``weights.text_weight_order`` order so the Rust runtime can bind device
buffers positionally.

Architectural knobs reproduced from the paper's zoo (configs.py):
GQA/MQA/MHA head layouts, gated SiLU vs gated GELU FFNs, and top-2 MoE
FFNs for the *-A3B analogs.  All large GEMMs run through the 4-bit
quantized Pallas kernel; attention state stays f32.

KV arena layout (shared with the Rust KV manager):
    kv[plane, 0=k|1=v, slot, kv_head, position, d_head]  f32
    plane 0           : logits mailbox (see below)
    plane 1 .. L      : layer l-1's K/V

Single-output convention: the PJRT execute wrapper returns multi-output
modules as ONE tuple-shaped device buffer whose elements can only be
read back through a full host literal copy — which would force the KV
arena through the host every step and destroy the zero-copy design.  So
every artifact returns exactly one array.  Decode/prefill write their
logits into arena plane 0 ("logits mailbox"): slot b's logits occupy
the first ceil(V/Dh)*Dh elements of plane[0, k=0, b, head=0], a
contiguous f32 range the Rust runtime reads back with a raw offset copy
(O(V) bytes) while the arena itself stays on device.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from .configs import KV_PAGE_SIZE, ModelConfig, Q4_GROUP
from .kernels.attention import decode_attention
from .kernels.quant_matmul import quant_matmul
from .weights import text_weight_order


class W:
    """Positional weight binder: yields arrays in declaration order."""

    def __init__(self, names: Sequence[str], arrays: Sequence[jnp.ndarray]):
        assert len(names) == len(arrays), (len(names), len(arrays))
        self._map = dict(zip(names, arrays))

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self._map[name]


def rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * g).astype(jnp.float32)


def rope(x, pos, theta):
    """Rotary position embedding.

    x:   [..., H, Dh] with Dh even; pos broadcastable to x[..., 0, 0].
    pos: integer positions, shape x.shape[:-2].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    ang = pos.astype(jnp.float32)[..., None, None] * freqs          # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def qmm(x, w: W, name: str):
    """Quantized matmul through the Pallas kernel."""
    return quant_matmul(x, w[name + ".q4"], w[name + ".scales"], Q4_GROUP)


def _ffn(cfg: ModelConfig, w: W, prefix: str, h):
    """Gated FFN (dense) or top-2 MoE FFN, on h [N, d]."""
    if cfg.moe is None:
        a = qmm(h, w, prefix + "w1")
        g = qmm(h, w, prefix + "w3")
        act = jax.nn.silu(a) if cfg.act == "silu" else jax.nn.gelu(a)
        return qmm(act * g, w, prefix + "w2")
    m = cfg.moe
    gate_logits = h @ w[prefix + "gate"]                      # [N, E]
    # Top-k via iterated argmax (NOT lax.top_k: jax>=0.5 lowers top_k to a
    # sort/topk form whose "largest" attribute the xla_extension 0.5.1
    # HLO-text parser rejects).  k is small and static, so this is cheap.
    remaining = gate_logits
    top_idx, top_vals = [], []
    for _ in range(m.top_k):
        idx = jnp.argmax(remaining, axis=-1)                  # [N]
        val = jnp.take_along_axis(remaining, idx[:, None], axis=-1)[:, 0]
        top_idx.append(idx)
        top_vals.append(val)
        remaining = remaining - jax.nn.one_hot(idx, m.n_experts) * 1e30
    top_w = jax.nn.softmax(jnp.stack(top_vals, axis=-1), axis=-1)  # [N, K]
    # Dense routing weights [N, E]: zero except the top-k entries.
    route = jnp.zeros_like(gate_logits)
    for k in range(m.top_k):
        route = route + jax.nn.one_hot(top_idx[k], m.n_experts) * top_w[:, k : k + 1]
    # Compute all experts densely (tiny sims) and mix: the semantics of
    # sparse top-2 routing with the arithmetic of a dense einsum.
    a = jnp.einsum("nd,edf->enf", h, w[prefix + "moe_w1"])
    g = jnp.einsum("nd,edf->enf", h, w[prefix + "moe_w3"])
    act = jax.nn.silu(a) if cfg.act == "silu" else jax.nn.gelu(a)
    y = jnp.einsum("enf,efd->end", act * g, w[prefix + "moe_w2"])  # [E, N, d]
    return jnp.einsum("end,ne->nd", y, route)


def kv_arena_shape(cfg: ModelConfig, batch: int):
    """Extended arena: plane 0 = logits mailbox, planes 1..L = layers."""
    return (cfg.n_layers + 1, 2, batch, cfg.n_kv_heads, cfg.s_max, cfg.d_head)


def logits_rows(cfg: ModelConfig) -> int:
    """Rows of the logits mailbox: ceil(vocab / d_head)."""
    return -(-cfg.vocab // cfg.d_head)




# ----------------------------------------------------------------- decode

def decode_fn(cfg: ModelConfig, tokens, pos, kv, *weights):
    """One generation step for a full batch slot arena.

    Args:
      tokens: [B] i32 current token per slot (pad slots feed token 0).
      pos:    [B] i32 position the new token occupies (== current length).
      kv:     arena [L, 2, B, Hkv, S_max, Dh] f32.
      weights: flat tuple per text_weight_order.

    Returns:
      Updated arena (single output; logits land in the plane-0 mailbox).

    Empty slots run garbage-in/garbage-out compute; the Rust scheduler
    masks them out.  Attention length is pos+1 (the new token's KV is
    written before attending).
    """
    w = W(text_weight_order(cfg), weights)
    b = tokens.shape[0]
    x = jnp.take(w["emb"], tokens, axis=0)                    # [B, d]
    lens = pos + 1

    # The output arena is assembled ONCE from per-layer planes at the
    # end (a single jnp.stack).  Updating `kv` in place with
    # kv.at[l].set(...) per layer makes XLA 0.5.1's CPU pipeline copy
    # the whole arena 2L times per step, which made decode superlinear
    # in batch size (EXPERIMENTS.md §Perf).
    planes = [None] * (cfg.n_layers + 1)

    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, w[p + "norm1"])
        q = qmm(h, w, p + "wq").reshape(b, cfg.n_q_heads, cfg.d_head)
        k = qmm(h, w, p + "wk").reshape(b, cfg.n_kv_heads, cfg.d_head)
        v = qmm(h, w, p + "wv").reshape(b, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        # Write the new token's K/V at `pos` in each slot's row.
        def write(cache, kk, p_):
            # cache [Hkv, S, Dh], kk [Hkv, Dh]
            return jax.lax.dynamic_update_slice(cache, kk[:, None, :], (0, p_, 0))

        k_cache = jax.vmap(write)(kv[l + 1, 0], k, pos)       # [B, Hkv, S, Dh]
        v_cache = jax.vmap(write)(kv[l + 1, 1], v, pos)
        planes[l + 1] = jnp.stack([k_cache, v_cache])         # [2, B, Hkv, S, Dh]

        attn = decode_attention(q, k_cache, v_cache, lens)    # [B, Hq, Dh]
        x = x + qmm(attn.reshape(b, cfg.d_q), w, p + "wo")
        h2 = rmsnorm(x, w[p + "norm2"])
        x = x + _ffn(cfg, w, p, h2)

    x = rmsnorm(x, w["norm_f"])
    logits = qmm(x, w, "unembed")                             # [B, vocab]

    # Plane 0: logits mailbox (layout in module docs).
    rows = logits_rows(cfg)
    pad = rows * cfg.d_head - cfg.vocab
    r = jnp.pad(logits, ((0, 0), (0, pad))).reshape(b, rows, cfg.d_head)
    mailbox = jnp.zeros((2, b, cfg.n_kv_heads, cfg.s_max, cfg.d_head), jnp.float32)
    mailbox = mailbox.at[0, :, 0, :rows, :].set(r)
    planes[0] = mailbox
    return jnp.stack(planes)                                  # [L+1, 2, B, ...]


# ---------------------------------------------------------------- prefill

def _prefill_body(cfg: ModelConfig, w: W, x, length):
    """Shared prefill trunk over embeddings x [S, d]; returns
    (x, plane list) — planes assembled into kv_one by the callers (one
    jnp.stack; see decode_fn for why not repeated in-place updates)."""
    s = x.shape[0]
    positions = jnp.arange(s, dtype=jnp.int32)
    valid = positions < length                                 # [S]
    causal = positions[None, :] <= positions[:, None]          # [S, S]
    mask = causal & valid[None, :]
    planes = [None] * (cfg.n_layers + 1)

    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, w[p + "norm1"])
        q = qmm(h, w, p + "wq").reshape(s, cfg.n_q_heads, cfg.d_head)
        k = qmm(h, w, p + "wk").reshape(s, cfg.n_kv_heads, cfg.d_head)
        v = qmm(h, w, p + "wv").reshape(s, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

        # Pad K/V to the S_max arena row (positions >= length hold
        # garbage; decode masks by length so it never reads them).
        k_pad = jnp.pad(jnp.transpose(k, (1, 0, 2)),
                        ((0, 0), (0, cfg.s_max - s), (0, 0)))  # [Hkv, Smax, Dh]
        v_pad = jnp.pad(jnp.transpose(v, (1, 0, 2)),
                        ((0, 0), (0, cfg.s_max - s), (0, 0)))
        planes[l + 1] = jnp.stack([k_pad[None], v_pad[None]])  # [2,1,Hkv,Smax,Dh]

        group = cfg.n_q_heads // cfg.n_kv_heads
        k_full = jnp.repeat(k, group, axis=1)                  # [S, Hq, Dh]
        v_full = jnp.repeat(v, group, axis=1)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
        logits_a = jnp.einsum("qhd,khd->hqk", q, k_full) * scale
        logits_a = jnp.where(mask[None], logits_a, -1e30)
        probs = jax.nn.softmax(logits_a, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", probs, v_full)       # [S, Hq, Dh]
        x = x + qmm(attn.reshape(s, cfg.d_q), w, p + "wo")
        h2 = rmsnorm(x, w[p + "norm2"])
        x = x + _ffn(cfg, w, p, h2)

    x = rmsnorm(x, w["norm_f"])
    return x, planes


def _assemble_kv_one(cfg: ModelConfig, planes, logits):
    """Stack prefill planes + the plane-0 logits mailbox into kv_one."""
    rows = logits_rows(cfg)
    pad = rows * cfg.d_head - cfg.vocab
    r = jnp.pad(logits, ((0, 0), (0, pad))).reshape(1, rows, cfg.d_head)
    mailbox = jnp.zeros((2, 1, cfg.n_kv_heads, cfg.s_max, cfg.d_head), jnp.float32)
    mailbox = mailbox.at[0, :, 0, :rows, :].set(r)
    planes[0] = mailbox
    return jnp.stack(planes)


def _spec_pack_dense(cfg: ModelConfig, planes, logits):
    """Assemble kv_one with ALL chunk rows' logits packed into plane 0.

    Layout: the whole plane-0 region of the single slot — both k/v
    sides, all heads, flattened to 2 * Hkv * s_max * Dh floats — holds
    the chunk's [C, vocab] logits row-major from offset 0, zero-padded.
    This is deliberately NOT the decode/prefill mailbox (head-0 k rows
    only): spec verify needs C * vocab floats, which outgrows the
    head-0 region at C=16 for the narrow-KV zoo models, and
    ``read_logits_chunk_c{C}`` is the layout's only reader.  The next
    decode step rebuilds plane 0 from zeros, wiping the packing, so the
    regular single-logits mailbox convention is undisturbed afterwards.
    """
    c, v = logits.shape
    region = 2 * cfg.n_kv_heads * cfg.s_max * cfg.d_head
    assert c * v <= region, (c, v, region)
    packed = jnp.pad(logits.reshape(-1), (0, region - c * v)).reshape(
        2, cfg.n_kv_heads, cfg.s_max, cfg.d_head)
    planes[0] = packed[:, None]                   # [2, 1, Hkv, S, Dh]
    return jnp.stack(planes)


def prefill_fn(cfg: ModelConfig, tokens, length, *weights):
    """Prompt processing for one sequence.

    Args:
      tokens: [S_bucket] i32, padded with 0 beyond `length`.
      length: scalar i32 number of valid tokens.

    Returns:
      kv_one [L+1, 2, 1, Hkv, S_max, Dh] ready for arena injection, with
      the last valid position's logits in the plane-0 mailbox.
    """
    w = W(text_weight_order(cfg), weights)
    x = jnp.take(w["emb"], tokens, axis=0)                    # [S, d]
    x, planes = _prefill_body(cfg, w, x, length)
    last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, cfg.d_model))  # [1, d]
    logits = qmm(last, w, "unembed")                          # [1, vocab]
    return _assemble_kv_one(cfg, planes, logits)


def prefill_embeds_fn(cfg: ModelConfig, embeds, length, *weights):
    """Prompt processing from raw embeddings (multimodal path).

    Identical to ``prefill_fn`` but the input is a pre-composed embedding
    sequence (vision embeddings ++ text-token embeddings) of shape
    [S_bucket, d].
    """
    w = W(text_weight_order(cfg), weights)
    x, planes = _prefill_body(cfg, w, embeds.astype(jnp.float32), length)
    last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, cfg.d_model))
    logits = qmm(last, w, "unembed")                          # [1, vocab]
    return _assemble_kv_one(cfg, planes, logits)


def embed_lookup_fn(cfg: ModelConfig, tokens, *weights):
    """Token-id -> embedding rows (host composes multimodal sequences)."""
    w = W(text_weight_order(cfg), weights)
    return jnp.take(w["emb"], tokens, axis=0)


# ------------------------------------------------------- chunked prefill

def _chunk_body(cfg: ModelConfig, w: W, x, start, length, kv_one,
                unembed_all=False):
    """Extend a partially-built kv_one by one chunk of embeddings.

    The chunk occupies absolute positions ``start .. start+length-1`` of
    the sequence.  Token-for-token this mirrors ``decode_fn`` — the same
    fused Pallas attention kernel runs with the chunk rows as the batch
    axis over a shared (broadcast) cache, and causality is enforced per
    row by ``lens`` exactly as a decode step enforces it.  Feeding a
    suffix in chunks therefore matches the token-by-token bucket-1
    decode path within fp tolerance with identical greedy argmax (NOT
    bit-exactly: XLA fuses [C, d] and [1, d] row blocks differently —
    empirically ~2e-6 max abs diff; the equivalence tests assert 2e-4
    plus argmax equality, the same batch-invariance contract the decode
    arena already relies on).

    Args:
      x:      [C, d] chunk embeddings (rows >= length are padding).
      start:  scalar i32, first absolute position of the chunk.
      length: scalar i32, valid rows in the chunk.
      kv_one: [L+1, 2, 1, Hkv, S_max, Dh] state built so far (positions
              < start are valid; everything else is garbage/zeros).

    Returns:
      Updated kv_one with the chunk's K/V written at its positions and
      the LAST valid chunk row's logits in the plane-0 mailbox — or,
      with ``unembed_all`` (the speculative-verify entries), ALL C
      rows' logits packed into plane 0 (see ``_spec_pack_dense``).
    """
    c = x.shape[0]
    offs = jnp.arange(c, dtype=jnp.int32)
    pos = start + offs                                         # [C] absolute
    valid = offs < length
    # Per-row attention length, as decode: the row's own K/V included.
    lens = jnp.where(valid, pos + 1, 1)
    # Scatter target rows; invalid rows write out of range -> dropped.
    pos_w = jnp.where(valid, pos, cfg.s_max)
    planes = [None] * (cfg.n_layers + 1)

    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, w[p + "norm1"])
        q = qmm(h, w, p + "wq").reshape(c, cfg.n_q_heads, cfg.d_head)
        k = qmm(h, w, p + "wk").reshape(c, cfg.n_kv_heads, cfg.d_head)
        v = qmm(h, w, p + "wv").reshape(c, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        # Write the chunk's K/V rows into the single cache row.
        k_cache = kv_one[l + 1, 0, 0]                          # [Hkv, S, Dh]
        v_cache = kv_one[l + 1, 1, 0]
        k_cache = k_cache.at[:, pos_w, :].set(
            jnp.transpose(k, (1, 0, 2)), mode="drop")
        v_cache = v_cache.at[:, pos_w, :].set(
            jnp.transpose(v, (1, 0, 2)), mode="drop")
        planes[l + 1] = jnp.stack([k_cache, v_cache])[:, None]  # [2,1,Hkv,S,Dh]

        # Same fused kernel as decode: chunk rows are the batch axis over
        # a shared cache; lens masks rows written by later chunk tokens.
        kb = jnp.broadcast_to(k_cache, (c,) + k_cache.shape)
        vb = jnp.broadcast_to(v_cache, (c,) + v_cache.shape)
        attn = decode_attention(q, kb, vb, lens)               # [C, Hq, Dh]
        x = x + qmm(attn.reshape(c, cfg.d_q), w, p + "wo")
        h2 = rmsnorm(x, w[p + "norm2"])
        x = x + _ffn(cfg, w, p, h2)

    x = rmsnorm(x, w["norm_f"])
    if unembed_all:
        # Speculative verify: every row's logits leave the device in one
        # readback, so the accept loop can score all K drafts at once.
        logits = qmm(x, w, "unembed")                          # [C, vocab]
        return _spec_pack_dense(cfg, planes, logits)
    last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, cfg.d_model))
    logits = qmm(last, w, "unembed")                           # [1, vocab]
    return _assemble_kv_one(cfg, planes, logits)


def prefill_chunk_fn(cfg: ModelConfig, tokens, start, length, kv_one, *weights):
    """Resume-capable prompt processing: extend kv_one by one token chunk.

    Args:
      tokens: [C_bucket] i32, padded with 0 beyond `length`.
      start:  scalar i32 absolute position of tokens[0].
      length: scalar i32 valid tokens in this chunk.
      kv_one: the state built by previous chunks (donated).
    """
    w = W(text_weight_order(cfg), weights)
    x = jnp.take(w["emb"], tokens, axis=0)                     # [C, d]
    return _chunk_body(cfg, w, x, start, length, kv_one)


def prefill_chunk_embeds_fn(cfg: ModelConfig, embeds, start, length, kv_one,
                            *weights):
    """Chunked prefill from raw embeddings (multimodal staged pipeline)."""
    w = W(text_weight_order(cfg), weights)
    return _chunk_body(cfg, w, embeds.astype(jnp.float32), start, length, kv_one)


# ------------------------------------------------ speculative verification

def spec_chunk_fn(cfg: ModelConfig, tokens, start, length, kv_one, *weights):
    """Speculative-decoding verifier over the dense kv_one
    (`spec_chunk_c{C}`).

    The chunk is ``[next_token, draft_1 .. draft_{K}]`` fed at the
    sequence's current length: row i's logits are the distribution
    after feeding ``tokens[0..=i]``.  Token-for-token this IS
    prefill_chunk_fn — same chunk body, same fused attention kernel,
    so each row is fp-equivalent to the tokenwise decode step that
    would have fed the same prefix, with identical greedy argmax (the
    chunked-catch-up equivalence contract) — except every row is
    unembedded and packed into plane 0 for one multi-position readback
    instead of only the last.
    """
    w = W(text_weight_order(cfg), weights)
    x = jnp.take(w["emb"], tokens, axis=0)                     # [C, d]
    return _chunk_body(cfg, w, x, start, length, kv_one, unembed_all=True)


def read_logits_chunk_fn(cfg: ModelConfig, c: int, kv):
    """Extract a spec_chunk packing: kv_one -> [C, vocab]
    (`read_logits_chunk_c{C}`) — the multi-position analog of
    read_logits_one."""
    flat = kv[0].reshape(-1)
    return flat[: c * cfg.vocab].reshape(c, cfg.vocab)


def zeros_fn(cfg: ModelConfig, batch: int):
    """Zero dense-arena state (reference only — the dense grids are no
    longer lowered; tests use this to pin the legacy layout math)."""
    return jnp.zeros(kv_arena_shape(cfg, batch), jnp.float32)


# ---------------------------------------------------------------- paged KV

def kv_pool_shape(cfg: ModelConfig):
    """Page-pool layout: the slot arena with `batch` -> physical pages
    and `s_max` -> KV_PAGE_SIZE.

        pool[plane, 0=k|1=v, page, kv_head, offset, d_head]  f32

    A sequence of length `len` owns ceil(len / page) KV pages named by
    its block table (block j covers absolute positions j*page ..
    j*page+page-1) plus one private mailbox page whose plane-0 k-side
    region (flattened [Hkv*page, Dh]) holds its last logits.  Page 0 is
    the reserved garbage sink: inactive decode lanes point their block
    tables and mailbox at it, so their garbage-in/garbage-out compute
    scatters harmlessly (it is never allocated, never read).
    """
    return (cfg.n_layers + 1, 2, cfg.kv_pool_pages(), cfg.n_kv_heads,
            KV_PAGE_SIZE, cfg.d_head)


def _mailbox_pad(cfg: ModelConfig, logits):
    """[N, vocab] logits -> [N, Hkv*page, Dh] page-plane rows (the
    mailbox region of a page, zero-padded past the logits)."""
    rows = logits_rows(cfg)
    n = logits.shape[0]
    region_rows = cfg.n_kv_heads * KV_PAGE_SIZE
    assert rows <= region_rows, (rows, region_rows)
    pad = rows * cfg.d_head - cfg.vocab
    r = jnp.pad(logits, ((0, 0), (0, pad))).reshape(n, rows, cfg.d_head)
    return jnp.pad(r, ((0, 0), (0, region_rows - rows), (0, 0)))


def _pool_mailbox_plane(cfg: ModelConfig, pool, mailbox, logits):
    """Plane 0 of the pool with `logits` written into the mailbox
    page(s).  Unlike the dense mailbox this is a scatter into the
    EXISTING plane, not a zero-fill: other sequences' mailbox pages
    (staged prefills mid-flight) must survive the step."""
    n_pages = pool.shape[2]
    region_rows = cfg.n_kv_heads * KV_PAGE_SIZE
    p0k = pool[0, 0].reshape(n_pages, region_rows, cfg.d_head)
    p0k = p0k.at[mailbox].set(_mailbox_pad(cfg, logits))
    return jnp.stack([
        p0k.reshape(n_pages, cfg.n_kv_heads, KV_PAGE_SIZE, cfg.d_head),
        pool[0, 1],
    ])


def _gather_pages(cfg: ModelConfig, plane, tables):
    """Gather per-sequence caches from a pool plane.

    plane:  [P, Hkv, page, Dh] (one layer, k or v side).
    tables: [..., n_blocks] i32 page ids.
    Returns [..., Hkv, s_max, Dh] — identical in shape and (valid)
    content to the dense arena row, so the same attention kernel runs
    byte-identically on it.
    """
    ps = KV_PAGE_SIZE
    nblk = tables.shape[-1]
    lead = tables.shape[:-1]
    flat = jnp.take(plane, tables.reshape(-1), axis=0)
    flat = flat.reshape(lead + (nblk, cfg.n_kv_heads, ps, cfg.d_head))
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + a for a in (1, 0, 2, 3))
    return jnp.transpose(flat, perm).reshape(
        lead + (cfg.n_kv_heads, nblk * ps, cfg.d_head))


def decode_paged_fn(cfg: ModelConfig, tokens, pos, tables, mailbox, pool,
                    *weights):
    """One generation step over the page pool (`decode_paged_b{B}`).

    Args:
      tokens:  [B] i32 current token per lane (pad lanes feed token 0).
      pos:     [B] i32 position the new token occupies.
      tables:  [B, n_blocks] i32 per-lane block tables (pad lanes and
               unallocated blocks point at page 0, the garbage sink).
      mailbox: [B] i32 per-lane mailbox page (pad lanes: page 0).
      pool:    kv_pool_shape(cfg) f32, donated.

    Returns the updated pool.  Token-for-token this is decode_fn with
    the dense arena row replaced by a block-table gather of the same
    [B, Hkv, s_max, Dh] shape; positions beyond `pos` are masked by the
    attention lengths either way, so greedy output is byte-identical to
    the slot arena.
    """
    w = W(text_weight_order(cfg), weights)
    b = tokens.shape[0]
    ps = KV_PAGE_SIZE
    x = jnp.take(w["emb"], tokens, axis=0)                    # [B, d]
    lens = pos + 1
    blk = pos // ps
    off = pos % ps
    # The page each lane's new token lands in.
    pg = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]  # [B]

    planes = [None] * (cfg.n_layers + 1)
    logits = None
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, w[p + "norm1"])
        q = qmm(h, w, p + "wq").reshape(b, cfg.n_q_heads, cfg.d_head)
        k = qmm(h, w, p + "wk").reshape(b, cfg.n_kv_heads, cfg.d_head)
        v = qmm(h, w, p + "wv").reshape(b, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        # Scatter the new token's K/V at (page, offset) per lane.  Pad
        # lanes all hit page 0 — duplicate garbage writes, never read.
        k_plane = pool[l + 1, 0].at[pg, :, off, :].set(k)     # [P,Hkv,ps,Dh]
        v_plane = pool[l + 1, 1].at[pg, :, off, :].set(v)
        planes[l + 1] = jnp.stack([k_plane, v_plane])

        k_cache = _gather_pages(cfg, k_plane, tables)          # [B,Hkv,S,Dh]
        v_cache = _gather_pages(cfg, v_plane, tables)
        attn = decode_attention(q, k_cache, v_cache, lens)     # [B, Hq, Dh]
        x = x + qmm(attn.reshape(b, cfg.d_q), w, p + "wo")
        h2 = rmsnorm(x, w[p + "norm2"])
        x = x + _ffn(cfg, w, p, h2)

    x = rmsnorm(x, w["norm_f"])
    logits = qmm(x, w, "unembed")                              # [B, vocab]
    planes[0] = _pool_mailbox_plane(cfg, pool, mailbox, logits)
    return jnp.stack(planes)


def _chunk_body_paged(cfg: ModelConfig, w: W, x, start, length, tables,
                      mailbox, pool, spec_pages=None):
    """_chunk_body over the page pool: extend one sequence's pages by a
    chunk of embeddings at absolute positions start..start+length-1.

    Shapes fed to the attention kernel match the dense chunk path
    exactly (the gather materializes the same [Hkv, s_max, Dh] cache
    view the kv_one held), so chunked prefill over pages is
    byte-identical to chunked prefill over a kv_one."""
    c = x.shape[0]
    ps = KV_PAGE_SIZE
    n_pages = pool.shape[2]
    offs = jnp.arange(c, dtype=jnp.int32)
    pos = start + offs                                         # [C] absolute
    valid = offs < length
    lens = jnp.where(valid, pos + 1, 1)
    pg = jnp.take(tables, pos // ps, axis=0)                   # [C]
    # Invalid rows scatter out of range -> dropped.
    pg_w = jnp.where(valid, pg, n_pages)
    off_w = pos % ps
    planes = [None] * (cfg.n_layers + 1)

    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        h = rmsnorm(x, w[p + "norm1"])
        q = qmm(h, w, p + "wq").reshape(c, cfg.n_q_heads, cfg.d_head)
        k = qmm(h, w, p + "wk").reshape(c, cfg.n_kv_heads, cfg.d_head)
        v = qmm(h, w, p + "wv").reshape(c, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        k_plane = pool[l + 1, 0].at[pg_w, :, off_w, :].set(k, mode="drop")
        v_plane = pool[l + 1, 1].at[pg_w, :, off_w, :].set(v, mode="drop")
        planes[l + 1] = jnp.stack([k_plane, v_plane])

        kseq = _gather_pages(cfg, k_plane, tables)             # [Hkv, S, Dh]
        vseq = _gather_pages(cfg, v_plane, tables)
        kb = jnp.broadcast_to(kseq, (c,) + kseq.shape)
        vb = jnp.broadcast_to(vseq, (c,) + vseq.shape)
        attn = decode_attention(q, kb, vb, lens)               # [C, Hq, Dh]
        x = x + qmm(attn.reshape(c, cfg.d_q), w, p + "wo")
        h2 = rmsnorm(x, w[p + "norm2"])
        x = x + _ffn(cfg, w, p, h2)

    x = rmsnorm(x, w["norm_f"])
    if spec_pages is not None:
        # Speculative verify: pack every row's logits across the
        # dedicated scratch pages; plane 0 (other sequences' mailboxes)
        # passes through untouched.
        logits = qmm(x, w, "unembed")                          # [C, vocab]
        planes[0] = pool[0]
        return _spec_pack_paged(cfg, jnp.stack(planes), spec_pages, logits)
    last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, cfg.d_model))
    logits = qmm(last, w, "unembed")                           # [1, vocab]
    planes[0] = _pool_mailbox_plane(cfg, pool, mailbox[None], logits)
    return jnp.stack(planes)


def _spec_pack_paged(cfg: ModelConfig, pool, spec_pages, logits):
    """Scatter [C, vocab] logits across the FULL planes (all layers,
    k and v sides) of the M dedicated scratch pages, row-major from the
    first page.  M = cfg.spec_scratch_pages(C); the scratch pages are
    never named by any block table, so every element of theirs is free
    real estate — unlike a mailbox page, whose plane-0 k-region alone
    is too small for C * vocab floats on the narrow-KV zoo models."""
    c, v = logits.shape
    m = spec_pages.shape[0]
    per = (cfg.n_layers + 1) * 2 * cfg.n_kv_heads * KV_PAGE_SIZE * cfg.d_head
    assert c * v <= m * per, (c, v, m, per)
    flat = jnp.pad(logits.reshape(-1), (0, m * per - c * v))
    vals = flat.reshape(m, cfg.n_layers + 1, 2, cfg.n_kv_heads,
                        KV_PAGE_SIZE, cfg.d_head)
    vals = jnp.transpose(vals, (1, 2, 0, 3, 4, 5))
    return pool.at[:, :, spec_pages].set(vals)


def prefill_chunk_paged_fn(cfg: ModelConfig, tokens, start, length, tables,
                           mailbox, pool, *weights):
    """Chunked prefill writing straight into the page pool
    (`prefill_chunk_paged_c{C}`): the staged-admission pipeline in paged
    mode builds sequences in place, so finishing a prefill costs no
    inject and caching its state costs no extract."""
    w = W(text_weight_order(cfg), weights)
    x = jnp.take(w["emb"], tokens, axis=0)                     # [C, d]
    return _chunk_body_paged(cfg, w, x, start, length, tables, mailbox, pool)


def prefill_chunk_embeds_paged_fn(cfg: ModelConfig, embeds, start, length,
                                  tables, mailbox, pool, *weights):
    """Paged chunked prefill from raw embeddings (multimodal)."""
    w = W(text_weight_order(cfg), weights)
    return _chunk_body_paged(cfg, w, embeds.astype(jnp.float32), start, length,
                             tables, mailbox, pool)


def adopt_paged_fn(cfg: ModelConfig, pool, kv_one, tables, mailbox):
    """Scatter a kv_one into the page pool (`adopt_paged`).

    The bridge from the one-shot prefill entries (which still produce
    dense kv_one states) into paged serving: all s_max positions are
    re-blocked onto the sequence's pages and the plane-0 mailbox logits
    move to its mailbox page.  Block-table entries past the sequence's
    allocation point at page 0, which absorbs the garbage tail.  One
    copy — the paged analog of the dense `inject`, paid only on the
    kv_one -> pages boundary (fresh one-shot prompts), never on cache
    hits.
    """
    ps = KV_PAGE_SIZE
    nblk = cfg.s_max // ps
    planes = [None] * (cfg.n_layers + 1)
    for l in range(cfg.n_layers):
        kp = kv_one[l + 1, 0, 0].reshape(cfg.n_kv_heads, nblk, ps, cfg.d_head)
        vp = kv_one[l + 1, 1, 0].reshape(cfg.n_kv_heads, nblk, ps, cfg.d_head)
        k_plane = pool[l + 1, 0].at[tables].set(jnp.transpose(kp, (1, 0, 2, 3)))
        v_plane = pool[l + 1, 1].at[tables].set(jnp.transpose(vp, (1, 0, 2, 3)))
        planes[l + 1] = jnp.stack([k_plane, v_plane])
    rows = logits_rows(cfg)
    logits = kv_one[0, 0, 0, 0, :rows, :].reshape(1, rows * cfg.d_head)
    logits = logits[:, : cfg.vocab]
    planes[0] = _pool_mailbox_plane(cfg, pool, mailbox[None], logits)
    return jnp.stack(planes)


def copy_page_fn(cfg: ModelConfig, pool, src, dst):
    """Copy page `src` over page `dst` across every plane (`copy_page`)
    — the copy-on-write primitive: a cache hit whose length is not
    page-aligned clones only its partially-filled tail page."""
    shape = kv_pool_shape(cfg)
    page = jax.lax.dynamic_slice(
        pool, (0, 0, src, 0, 0, 0),
        (shape[0], 2, 1, cfg.n_kv_heads, KV_PAGE_SIZE, cfg.d_head))
    return jax.lax.dynamic_update_slice(pool, page, (0, 0, dst, 0, 0, 0))


def zeros_pool_fn(cfg: ModelConfig):
    """Device-side zero page pool allocator (`zeros_pool`)."""
    return jnp.zeros(kv_pool_shape(cfg), jnp.float32)


def read_logits_page_fn(cfg: ModelConfig, pool, page):
    """Extract one mailbox page's logits: pool, page -> [vocab]
    (`read_logits_page`) — the paged analog of read_logits_one."""
    region = jax.lax.dynamic_slice(
        pool, (0, 0, page, 0, 0, 0),
        (1, 1, 1, cfg.n_kv_heads, KV_PAGE_SIZE, cfg.d_head))
    return region.reshape(-1)[: cfg.vocab]


def spec_chunk_paged_fn(cfg: ModelConfig, tokens, start, length, tables,
                        spec_pages, pool, *weights):
    """Speculative-decoding verifier over the page pool
    (`spec_chunk_paged_c{C}`): prefill_chunk_paged_fn with every row
    unembedded and packed across the scratch pages (see spec_chunk_fn
    for the row semantics).  The caller must have covered positions
    start .. start+length-1 with PRIVATE pages (copy-on-write any
    shared tail first): the chunk scatters draft K/V into them, and a
    rejected draft's page-tail writes are rolled back host-side by
    releasing the pages past the accepted length."""
    w = W(text_weight_order(cfg), weights)
    x = jnp.take(w["emb"], tokens, axis=0)                     # [C, d]
    return _chunk_body_paged(cfg, w, x, start, length, tables, None, pool,
                             spec_pages=spec_pages)


def read_logits_chunk_paged_fn(cfg: ModelConfig, c: int, pool, spec_pages):
    """Extract a spec_chunk_paged packing: pool, spec_pages ->
    [C, vocab] (`read_logits_chunk_paged_c{C}`)."""
    region = jnp.take(pool, spec_pages, axis=2)   # [L+1, 2, M, Hkv, ps, Dh]
    region = jnp.transpose(region, (2, 0, 1, 3, 4, 5))
    return region.reshape(-1)[: c * cfg.vocab].reshape(c, cfg.vocab)


# ------------------------------------------------- dense reference graphs
#
# The dense single-arena functions below (inject/extract, and the
# prefill/decode graphs above) are NOT lowered as artifacts anymore —
# serving is paged-only.  They remain as python-level references: the
# equivalence tests pin the paged grids bit-exactly against them, and
# reference_generate drives them as the greedy oracle.

def inject_fn(cfg: ModelConfig, arena, kv_one, slot):
    """Insert a prefilled single-sequence KV row into arena slot `slot`."""
    return jax.lax.dynamic_update_slice(arena, kv_one, (0, 0, slot, 0, 0, 0))


def extract_fn(cfg: ModelConfig, arena, slot):
    """Extract arena slot `slot` as a single-sequence KV row (all planes,
    including the logits mailbox — its content is stale but harmless)."""
    l1, two, _, hkv, s, dh = arena.shape
    return jax.lax.dynamic_slice(arena, (0, 0, slot, 0, 0, 0), (l1, two, 1, hkv, s, dh))


# ----------------------------------------------------- python-side oracle

def read_logits_fn(cfg: ModelConfig, kv):
    """Extract the plane-0 logits mailbox for every slot: kv -> [B, vocab].

    Reference only — no longer lowered.  The serving path reads one
    mailbox *page* at a time (`read_logits_page`); this keeps the dense
    mailbox layout contract testable against that extractor.
    """
    rows = logits_rows(cfg)
    b = kv.shape[2]
    r = kv[0, 0, :, 0, :rows, :]                  # [B, rows, Dh]
    return r.reshape(b, rows * cfg.d_head)[:, : cfg.vocab]


def read_logits_one_fn(cfg: ModelConfig, kv, slot):
    """Extract ONE slot's plane-0 mailbox: kv, slot -> [vocab].

    Reference only — no longer lowered.  Kept so tests can assert the
    sparse single-slot readback math against the full-batch extractor.
    """
    rows = logits_rows(cfg)
    plane = kv[0, 0]                              # [B, Hkv, S, Dh]
    row = jax.lax.dynamic_slice(
        plane, (slot, 0, 0, 0), (1, 1, rows, cfg.d_head))
    return row.reshape(rows * cfg.d_head)[: cfg.vocab]


def read_logits_mailbox(cfg: ModelConfig, kv, slot: int):
    """Host-side mirror of the Rust raw-offset logits readback."""
    rows = logits_rows(cfg)
    flat = kv[0, 0, slot, 0, :rows, :].reshape(-1)
    return flat[: cfg.vocab]


def reference_generate(cfg: ModelConfig, weights: Dict, prompt: List[int],
                       n_new: int) -> List[int]:
    """Greedy generation oracle (numpy-level, used by tests and to verify
    the Rust engine token-for-token)."""
    order = text_weight_order(cfg)
    arrs = [jnp.asarray(weights[n]) for n in order]
    s_bucket = next(b for b in cfg.prefill_buckets if b >= len(prompt))
    toks = jnp.zeros(s_bucket, jnp.int32).at[: len(prompt)].set(jnp.asarray(prompt))
    kv_one = prefill_fn(cfg, toks, jnp.asarray(len(prompt), jnp.int32), *arrs)
    arena = inject_fn(cfg, jnp.zeros(kv_arena_shape(cfg, 1), jnp.float32), kv_one,
                      jnp.asarray(0, jnp.int32))
    out = [int(jnp.argmax(read_logits_mailbox(cfg, arena, 0)))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        arena = decode_fn(
            cfg,
            jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32),
            arena,
            *arrs,
        )
        out.append(int(jnp.argmax(read_logits_mailbox(cfg, arena, 0))))
        pos += 1
    return out
