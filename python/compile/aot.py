"""AOT lowering driver: L2/L1 python -> artifacts/ for the Rust runtime.

Emits, per model in the zoo:
  artifacts/<model>/<entry>.hlo.txt   HLO *text* (xla_extension 0.5.1
                                      rejects jax>=0.5 serialized protos;
                                      the text parser reassigns ids)
  artifacts/<model>.umw               weight blob (weights are runtime
                                      arguments, not baked constants)
plus artifacts/tokenizer.json and artifacts/manifest.json describing
every entry's argument order/shapes/dtypes so Rust can bind buffers
positionally.

Usage:  python -m compile.aot --out-dir ../artifacts [--models a,b] [--force]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import vision as V
from .configs import (
    EMBED_PREFILL_BUCKETS,
    KV_PAGE_SIZE,
    MODELS,
    PREFILL_CHUNK_BUCKETS,
    SPEC_CHUNK_BUCKETS,
    VISION_BATCH_BUCKETS,
    ModelConfig,
)
from .tokenizer_train import export as export_tokenizer
from .weights import build_weights, text_weight_order, vision_weight_order, write_umw

F32, I32 = jnp.float32, jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    return_tuple=False: every artifact returns exactly ONE array so the
    executed PJRT output buffer is array-shaped and can be threaded
    directly into the next execute_b call (device-resident KV pool).
    Multi-output modules come back as a single tuple buffer that can only
    be read through a host literal copy — see model.py's logits-mailbox
    convention.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def weight_specs(weights, order):
    return [spec(weights[n].shape, weights[n].dtype) for n in order]


def arg_desc(name, kind, s):
    return {
        "name": name,
        "kind": kind,  # "input" | "weight"
        "dtype": str(np.dtype(s.dtype)),
        "shape": list(s.shape),
    }


class EntryBuilder:
    """Lowers one model's entries and records manifest metadata."""

    def __init__(self, cfg: ModelConfig, weights, out_dir: str, force: bool):
        self.cfg = cfg
        self.weights = weights
        self.dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.force = force
        self.entries = {}
        self.t_order = text_weight_order(cfg)
        self.t_specs = weight_specs(weights, self.t_order)

    def lower(self, entry: str, fn, input_descs, inputs_specs, weight_order, weight_specs_,
              donate=()):
        path = os.path.join(self.dir, f"{entry}.hlo.txt")
        self.entries[entry] = {
            "file": f"{self.cfg.name}/{entry}.hlo.txt",
            "args": input_descs
            + [arg_desc(n, "weight", s) for n, s in zip(weight_order, weight_specs_)],
            "donated": list(donate),
        }
        if not self.force and os.path.exists(path):
            return
        t0 = time.time()
        # keep_unused=True: parameter lists must match the manifest even
        # when an entry ignores some weights (e.g. embed_lookup).
        # donate_argnums: pool-sized inputs are donated so XLA updates
        # them in place — without this every decode step copies the whole
        # KV pool and batching scales inversely (EXPERIMENTS.md §Perf).
        lowered = jax.jit(fn, keep_unused=True, donate_argnums=tuple(donate)).lower(
            *inputs_specs, *weight_specs_)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {self.cfg.name}/{entry}: {len(text)/1e3:.0f} kB in {time.time()-t0:.1f}s",
              flush=True)

    # ---- paged-KV entries ------------------------------------------------
    #
    # Serving is paged-only: the dense single-arena graphs
    # (decode_b/prefill_s/inject_b/extract_b/...) are no longer lowered —
    # they survive in model.py as python-level references that the
    # equivalence tests pin the paged grids against.

    def decode_paged(self, b: int):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        nblk = cfg.kv_blocks_per_seq()
        self.lower(
            f"decode_paged_b{b}",
            functools.partial(M.decode_paged_fn, cfg),
            [
                arg_desc("tokens", "input", spec((b,), I32)),
                arg_desc("pos", "input", spec((b,), I32)),
                arg_desc("tables", "input", spec((b, nblk), I32)),
                arg_desc("mailbox", "input", spec((b,), I32)),
                arg_desc("pool", "input", pool),
            ],
            [spec((b,), I32), spec((b,), I32), spec((b, nblk), I32),
             spec((b,), I32), pool],
            self.t_order,
            self.t_specs,
            donate=(4,),
        )

    def prefill_chunk_paged(self, c: int):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        nblk = cfg.kv_blocks_per_seq()
        self.lower(
            f"prefill_chunk_paged_c{c}",
            functools.partial(M.prefill_chunk_paged_fn, cfg),
            [
                arg_desc("tokens", "input", spec((c,), I32)),
                arg_desc("start", "input", spec((), I32)),
                arg_desc("length", "input", spec((), I32)),
                arg_desc("tables", "input", spec((nblk,), I32)),
                arg_desc("mailbox", "input", spec((), I32)),
                arg_desc("pool", "input", pool),
            ],
            [spec((c,), I32), spec((), I32), spec((), I32), spec((nblk,), I32),
             spec((), I32), pool],
            self.t_order,
            self.t_specs,
            donate=(5,),
        )

    def prefill_chunk_embeds_paged(self, c: int):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        nblk = cfg.kv_blocks_per_seq()
        self.lower(
            f"prefill_chunk_embeds_paged_c{c}",
            functools.partial(M.prefill_chunk_embeds_paged_fn, cfg),
            [
                arg_desc("embeds", "input", spec((c, cfg.d_model), F32)),
                arg_desc("start", "input", spec((), I32)),
                arg_desc("length", "input", spec((), I32)),
                arg_desc("tables", "input", spec((nblk,), I32)),
                arg_desc("mailbox", "input", spec((), I32)),
                arg_desc("pool", "input", pool),
            ],
            [spec((c, cfg.d_model), F32), spec((), I32), spec((), I32),
             spec((nblk,), I32), spec((), I32), pool],
            self.t_order,
            self.t_specs,
            donate=(5,),
        )

    def spec_chunk_paged(self, c: int):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        nblk = cfg.kv_blocks_per_seq()
        m = cfg.spec_scratch_pages(c)
        self.lower(
            f"spec_chunk_paged_c{c}",
            functools.partial(M.spec_chunk_paged_fn, cfg),
            [
                arg_desc("tokens", "input", spec((c,), I32)),
                arg_desc("start", "input", spec((), I32)),
                arg_desc("length", "input", spec((), I32)),
                arg_desc("tables", "input", spec((nblk,), I32)),
                arg_desc("spec_pages", "input", spec((m,), I32)),
                arg_desc("pool", "input", pool),
            ],
            [spec((c,), I32), spec((), I32), spec((), I32), spec((nblk,), I32),
             spec((m,), I32), pool],
            self.t_order,
            self.t_specs,
            donate=(5,),
        )

    def read_logits_chunk_paged(self, c: int):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        m = cfg.spec_scratch_pages(c)
        self.lower(
            f"read_logits_chunk_paged_c{c}",
            functools.partial(M.read_logits_chunk_paged_fn, cfg, c),
            [
                arg_desc("pool", "input", pool),
                arg_desc("spec_pages", "input", spec((m,), I32)),
            ],
            [pool, spec((m,), I32)],
            [],
            [],
        )

    def copy_page(self):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        self.lower(
            "copy_page",
            functools.partial(M.copy_page_fn, cfg),
            [
                arg_desc("pool", "input", pool),
                arg_desc("src", "input", spec((), I32)),
                arg_desc("dst", "input", spec((), I32)),
            ],
            [pool, spec((), I32), spec((), I32)],
            [],
            [],
            donate=(0,),
        )

    def zeros_pool(self):
        self.lower(
            "zeros_pool",
            functools.partial(M.zeros_pool_fn, self.cfg),
            [],
            [],
            [],
            [],
        )

    def read_logits_page(self):
        cfg = self.cfg
        pool = spec(M.kv_pool_shape(cfg), F32)
        self.lower(
            "read_logits_page",
            functools.partial(M.read_logits_page_fn, cfg),
            [
                arg_desc("pool", "input", pool),
                arg_desc("page", "input", spec((), I32)),
            ],
            [pool, spec((), I32)],
            [],
            [],
        )

    def embed_lookup(self, s: int):
        cfg = self.cfg
        self.lower(
            f"embed_lookup_s{s}",
            functools.partial(M.embed_lookup_fn, cfg),
            [arg_desc("tokens", "input", spec((s,), I32))],
            [spec((s,), I32)],
            self.t_order,
            self.t_specs,
        )

    def vision(self, resolution: int):
        cfg = self.cfg
        vc = cfg.vision
        p = vc.n_patches(resolution)
        v_order = vision_weight_order(cfg)
        v_specs = weight_specs(self.weights, v_order)
        self.lower(
            f"vision_r{resolution}",
            functools.partial(V.vision_encode_fn, cfg),
            [arg_desc("patches", "input", spec((p, vc.patch_dim), F32))],
            [spec((p, vc.patch_dim), F32)],
            v_order,
            v_specs,
        )

    def vision_batch(self, resolution: int, b: int):
        cfg = self.cfg
        vc = cfg.vision
        p = vc.n_patches(resolution)
        v_order = vision_weight_order(cfg)
        v_specs = weight_specs(self.weights, v_order)
        self.lower(
            f"vision_r{resolution}_b{b}",
            functools.partial(V.vision_encode_batch_fn, cfg),
            [arg_desc("patches", "input", spec((b, p, vc.patch_dim), F32))],
            [spec((b, p, vc.patch_dim), F32)],
            v_order,
            v_specs,
        )


def build_model(cfg: ModelConfig, out_dir: str, force: bool) -> dict:
    print(f"model {cfg.name} ({cfg.paper_name}, ~{cfg.n_params()/1e6:.2f}M sim params)",
          flush=True)
    weights = build_weights(cfg)
    umw_path = os.path.join(out_dir, f"{cfg.name}.umw")
    if force or not os.path.exists(umw_path):
        nbytes = write_umw(umw_path, weights)
        print(f"  weights: {nbytes/1e6:.1f} MB -> {cfg.name}.umw", flush=True)

    eb = EntryBuilder(cfg, weights, out_dir, force)
    # Paged-only serving surface: per-bucket decode over block tables,
    # chunked prefill straight onto pages (fresh prompts and catch-up
    # resume alike), and the speculative verify grids.  The pool entries
    # are bucket-independent — one pool serves every decode bucket, so
    # grow/shrink swaps executables without touching KV, and >16 active
    # lanes run as repeated largest-bucket dispatches over disjoint
    # block-table slices (lane virtualization; see
    # configs.DECODE_VIRTUAL_FACTOR).
    for b in cfg.decode_buckets:
        eb.decode_paged(b)
    for c in PREFILL_CHUNK_BUCKETS:
        eb.prefill_chunk_paged(c)
    for c in SPEC_CHUNK_BUCKETS:
        eb.spec_chunk_paged(c)
        eb.read_logits_chunk_paged(c)
    eb.copy_page()
    eb.zeros_pool()
    eb.read_logits_page()
    if cfg.vision:
        for s in EMBED_PREFILL_BUCKETS:
            eb.embed_lookup(s)
        for c in PREFILL_CHUNK_BUCKETS:
            eb.prefill_chunk_embeds_paged(c)
        for r in cfg.vision.resolutions:
            eb.vision(r)
            for b in VISION_BATCH_BUCKETS:
                eb.vision_batch(r, b)

    meta = {
        "paper_name": cfg.paper_name,
        "weights_file": f"{cfg.name}.umw",
        "n_params": cfg.n_params(),
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_q_heads": cfg.n_q_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "d_head": cfg.d_head,
        "d_ffn": cfg.d_ffn,
        "vocab": cfg.vocab,
        "s_max": cfg.s_max,
        "act": cfg.act,
        "moe": (
            {"n_experts": cfg.moe.n_experts, "top_k": cfg.moe.top_k,
             "d_expert": cfg.moe.d_expert}
            if cfg.moe else None
        ),
        "decode_buckets": list(cfg.decode_buckets),
        "prefill_buckets": list(cfg.prefill_buckets),
        "prefill_chunk_buckets": list(PREFILL_CHUNK_BUCKETS),
        "spec_chunk_buckets": list(SPEC_CHUNK_BUCKETS),
        "spec_scratch_pages": {
            str(c): cfg.spec_scratch_pages(c) for c in SPEC_CHUNK_BUCKETS
        },
        "embed_prefill_buckets": list(EMBED_PREFILL_BUCKETS) if cfg.vision else [],
        "kv_page_size": KV_PAGE_SIZE,
        "kv_pool_pages": cfg.kv_pool_pages(),
        "decode_virtual_lanes": cfg.decode_virtual_lanes(),
        "vision": (
            {
                "d_model": cfg.vision.d_model,
                "n_layers": cfg.vision.n_layers,
                "patch": cfg.vision.patch,
                "merge": cfg.vision.merge,
                "batch_buckets": list(VISION_BATCH_BUCKETS),
                "resolutions": list(cfg.vision.resolutions),
                "n_patches": {str(r): cfg.vision.n_patches(r) for r in cfg.vision.resolutions},
                "n_visual_tokens": {
                    str(r): cfg.vision.n_visual_tokens(r) for r in cfg.vision.resolutions
                },
                "patch_dim": cfg.vision.patch_dim,
            }
            if cfg.vision else None
        ),
        "entries": eb.entries,
    }
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="", help="comma-separated subset")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    names = [n for n in args.models.split(",") if n] or list(MODELS)

    tok_path = os.path.join(out_dir, "tokenizer.json")
    tok = export_tokenizer(tok_path, vocab_size=2048)
    print(f"tokenizer: {len(tok['merges'])} merges -> tokenizer.json", flush=True)

    # Merge into any existing manifest so `--models subset` re-lowers
    # don't drop the other models' entries.
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"format": 1, "tokenizer": "tokenizer.json", "models": {}}
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except Exception:
            pass
    t0 = time.time()
    for name in names:
        manifest["models"][name] = build_model(MODELS[name], out_dir, args.force)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json written; total {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
