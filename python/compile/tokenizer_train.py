"""Build-time byte-level BPE tokenizer training.

The Rust engine needs a real tokenizer (the paper streams multi-byte
UTF-8 cleanly, which only matters if tokens can split codepoints — byte
level BPE does exactly that).  We train a small merge table over an
embedded corpus at artifact-build time and export it as JSON; the Rust
side implements encode (rank-greedy merging, GPT-2 style) and
incremental UTF-8-safe decode.

Vocabulary layout:
    0..3     specials: <pad>=0 <bos>=1 <eos>=2 <img>=3
    4..259   the 256 raw bytes
    260..    merge tokens, id = 260 + merge_rank
"""

from __future__ import annotations

import json
from collections import Counter
from typing import Dict, List, Tuple

PAD, BOS, EOS, IMG = 0, 1, 2, 3
N_SPECIAL = 4

CORPUS = """
Apple Silicon has rapidly become a significant platform for machine
learning development and deployment. With unified memory architectures
offering shared CPU and GPU memory, recent devices provide compelling
capabilities for running large language models locally. Continuous
batching dynamically groups requests to maximize throughput, allowing
new requests to join mid-generation and completed requests to exit
without blocking others. Vision-language models must process images
through a vision encoder on every request, even when the same image
appears across multiple conversation turns. Content-based prefix
caching eliminates redundant vision encoding by identifying identical
images through content hashing, regardless of input format.
The quick brown fox jumps over the lazy dog. Pack my box with five
dozen liquor jugs. How vexingly quick daft zebras jump! The five boxing
wizards jump quickly. Sphinx of black quartz, judge my vow.
def generate(prompt, max_tokens=128): return engine.run(prompt)
for request in queue: batch.add(request) if len(batch) < max_batch
print("hello world"); assert response.status_code == 200
{"model": "qwen3-0.6b", "messages": [{"role": "user", "content": "hi"}]}
0123456789 !@#$%^&*()_+-=[]{}|;:',.<>?/~`
El rapido zorro marron salta sobre el perro perezoso. La inferencia
multimodal eficiente requiere almacenamiento en cache de prefijos.
Die schnelle Entwicklung effizienter Inferenz auf Verbraucher-Hardware
ermoglicht datenschutzfreundliche Anwendungen ohne Cloud-Dienste.
tok/s latency TTFT throughput KV-cache prefill decode batch scheduler
llama qwen gemma nemotron vision encoder embedding resolution frames
"""


def train_bpe(corpus: str, vocab_size: int) -> List[Tuple[int, int]]:
    """Train byte-level BPE; returns the ordered merge list.

    Each merge is a pair of token ids (byte ids are 4..259; merge ids
    start at 260).  Classic greedy highest-frequency pair algorithm over
    whitespace-split words.
    """
    words = Counter(corpus.split())
    # Each word as a tuple of byte token ids.
    seqs: Dict[Tuple[int, ...], int] = {
        tuple(b + N_SPECIAL for b in w.encode("utf-8")): c for w, c in words.items()
    }
    merges: List[Tuple[int, int]] = []
    next_id = N_SPECIAL + 256
    while next_id < vocab_size:
        pairs: Counter = Counter()
        for seq, cnt in seqs.items():
            for a, b in zip(seq, seq[1:]):
                pairs[(a, b)] += cnt
        if not pairs:
            break
        (a, b), freq = pairs.most_common(1)[0]
        if freq < 2:
            break
        merges.append((a, b))
        new_seqs: Dict[Tuple[int, ...], int] = {}
        for seq, cnt in seqs.items():
            out: List[int] = []
            i = 0
            while i < len(seq):
                if i + 1 < len(seq) and seq[i] == a and seq[i + 1] == b:
                    out.append(next_id)
                    i += 2
                else:
                    out.append(seq[i])
                    i += 1
            new_seqs[tuple(out)] = new_seqs.get(tuple(out), 0) + cnt
        seqs = new_seqs
        next_id += 1
    return merges


def encode(text: str, merges: List[Tuple[int, int]]) -> List[int]:
    """Reference encoder (rank-greedy, mirrors the Rust implementation)."""
    rank = {pair: i for i, pair in enumerate(merges)}
    out: List[int] = []
    for word in _split_keep_spaces(text):
        seq = [b + N_SPECIAL for b in word.encode("utf-8")]
        while len(seq) > 1:
            best, best_rank = None, None
            for i, pair in enumerate(zip(seq, seq[1:])):
                r = rank.get(pair)
                if r is not None and (best_rank is None or r < best_rank):
                    best, best_rank = i, r
            if best is None:
                break
            seq[best : best + 2] = [260 + best_rank]
        out.extend(seq)
    return out


def decode_bytes(ids: List[int], merges: List[Tuple[int, int]]) -> bytes:
    """Reference decoder: expand merge tokens back to bytes."""
    out = bytearray()

    def expand(tok: int):
        if tok < N_SPECIAL:
            return
        if tok < N_SPECIAL + 256:
            out.append(tok - N_SPECIAL)
            return
        a, b = merges[tok - (N_SPECIAL + 256)]
        expand(a)
        expand(b)

    for t in ids:
        expand(t)
    return bytes(out)


def _split_keep_spaces(text: str) -> List[str]:
    """Split into words, attaching each run of spaces to the following
    word (GPT-2-ish pre-tokenization, simplified)."""
    parts: List[str] = []
    cur = ""
    for ch in text:
        if ch.isspace():
            if cur and not cur[-1].isspace():
                parts.append(cur)
                cur = ""
            cur += ch
        else:
            cur += ch
    if cur:
        parts.append(cur)
    return parts


def export(path: str, vocab_size: int) -> dict:
    merges = train_bpe(CORPUS, vocab_size)
    spec = {
        "vocab_size": vocab_size,
        "n_special": N_SPECIAL,
        "specials": {"pad": PAD, "bos": BOS, "eos": EOS, "img": IMG},
        "merges": merges,
    }
    with open(path, "w") as f:
        json.dump(spec, f)
    return spec
