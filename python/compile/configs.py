"""Sim model zoo configuration (DESIGN.md §4).

Each entry is a scaled-down architectural analog of one of the paper's
evaluated checkpoints.  Dimensions are multiples of 32 so every large
GEMM can run through the 4-bit quantized Pallas kernel (group size 32,
nibble packing needs even K).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

S_MAX = 640          # KV arena length: 512 prompt + 128 generation
VOCAB = 2048
Q4_GROUP = 32

# Paged-KV page size in token positions.  Must divide S_MAX so one
# sequence maps to exactly S_MAX // KV_PAGE_SIZE block-table entries,
# and the per-page mailbox region (plane 0, k side: n_kv_heads *
# KV_PAGE_SIZE * d_head floats) must cover VOCAB for every model in the
# zoo (smallest: qwen3-0.6b at 2*64*16 = 2048 = VOCAB).
KV_PAGE_SIZE = 64

# Decode lane virtualization factor: the engine serves up to
# FACTOR * max(decode_buckets) concurrent decode lanes by issuing
# repeated largest-bucket `decode_paged_b{B}` dispatches over disjoint
# block-table slices; the pool is sized so every virtual lane can hold
# a full-length sequence (see ModelConfig.kv_pool_pages).
DECODE_VIRTUAL_FACTOR = 4


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_expert: int


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    d_model: int
    n_layers: int
    n_heads: int
    patch: int = 32          # pixels per patch side
    merge: int = 2           # spatial merge factor -> visual tokens
    # Supported input resolutions (square), must map to integer grids.
    resolutions: Tuple[int, ...] = (224, 448, 768, 1024)

    def grid(self, resolution: int) -> int:
        return resolution // self.patch

    def n_patches(self, resolution: int) -> int:
        return self.grid(resolution) ** 2

    def n_visual_tokens(self, resolution: int) -> int:
        g = self.grid(resolution)
        gm = (g + self.merge - 1) // self.merge
        return gm * gm

    @property
    def patch_dim(self) -> int:
        return 3 * self.patch * self.patch


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    paper_name: str
    d_model: int
    n_layers: int
    n_q_heads: int
    n_kv_heads: int
    d_head: int
    d_ffn: int
    act: str = "silu"              # "silu" (gated) | "gelu" (gated)
    moe: Optional[MoeConfig] = None
    vision: Optional[VisionConfig] = None
    vocab: int = VOCAB
    s_max: int = S_MAX
    rope_theta: float = 10000.0
    # Decode batch buckets lowered for this model.
    decode_buckets: Tuple[int, ...] = (1, 8)
    # Prefill sequence buckets lowered for this model.
    prefill_buckets: Tuple[int, ...] = (32, 128, 512)

    @property
    def d_q(self) -> int:
        return self.n_q_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def logits_rows(self) -> int:
        """Rows of the plane-0 logits mailbox: ceil(vocab / d_head)."""
        return -(-self.vocab // self.d_head)

    def kv_blocks_per_seq(self) -> int:
        """Block-table length: pages covering one s_max-long sequence."""
        assert self.s_max % KV_PAGE_SIZE == 0, (self.s_max, KV_PAGE_SIZE)
        return self.s_max // KV_PAGE_SIZE

    def decode_virtual_lanes(self) -> int:
        """Decode-lane ceiling served by lane virtualization.

        `decode_paged_b{B}` executables top out at the largest lowered
        bucket, but the pool is bucket-independent: the engine packs
        more active lanes into repeated largest-bucket dispatches over
        disjoint block-table slices, so the serving ceiling is set by
        pool capacity, not by lowering.  Virtual lanes are sized at 4x
        the largest lowered bucket (64 for the text zoo) — past that,
        unified-memory capacity is the binding resource and admission
        backpressure takes over.
        """
        return DECODE_VIRTUAL_FACTOR * max(self.decode_buckets)

    def kv_pool_pages(self) -> int:
        """Physical pages in the paged-KV pool lowered for this model.

        Sized so every *virtual* decode lane (see decode_virtual_lanes:
        4x the largest lowered bucket) can hold a full-length sequence
        — blocks plus one mailbox page each.  The Rust allocator
        reserves page 0 as the garbage sink for inactive decode lanes
        and may cap its *usable* budget below this at run time (the
        paged-KV ablation does); this constant only fixes the lowered
        pool shape.
        """
        return self.decode_virtual_lanes() * (self.kv_blocks_per_seq() + 1)

    def spec_scratch_pages(self, c: int) -> int:
        """Scratch pages holding a packed [C, vocab] logits readback
        region for the paged speculative-verify entries
        (`spec_chunk_paged_c{C}`): each dedicated scratch page
        contributes its FULL (L+1) * 2 * Hkv * page * Dh floats (all
        planes, k and v) — scratch pages are never in any block table,
        so every element is free real estate.
        """
        per = (self.n_layers + 1) * 2 * self.n_kv_heads * KV_PAGE_SIZE * self.d_head
        return -(-(c * self.vocab) // per)

    def n_params(self) -> int:
        """Approximate parameter count (for logs / DESIGN cross-check)."""
        d, f, v = self.d_model, self.d_ffn, self.vocab
        per_layer = d * self.d_q + 2 * d * self.d_kv + self.d_q * d + 2 * d
        if self.moe:
            per_layer += d * self.moe.n_experts + 3 * d * self.moe.d_expert * self.moe.n_experts
        else:
            per_layer += 3 * d * f
        total = self.n_layers * per_layer + 2 * v * d + d
        if self.vision:
            vc = self.vision
            total += vc.patch_dim * vc.d_model + vc.n_layers * (4 * vc.d_model**2 + 8 * vc.d_model**2)
        return total


FULL_BUCKETS = (1, 2, 4, 8, 16)

MODELS = {
    m.name: m
    for m in [
        ModelConfig(
            name="qwen3-0.6b", paper_name="Qwen3-0.6B",
            d_model=64, n_layers=2, n_q_heads=4, n_kv_heads=2, d_head=16,
            d_ffn=256, decode_buckets=FULL_BUCKETS,
        ),
        ModelConfig(
            name="qwen3-4b", paper_name="Qwen3-4B",
            d_model=128, n_layers=4, n_q_heads=4, n_kv_heads=2, d_head=32,
            d_ffn=512, decode_buckets=FULL_BUCKETS,
        ),
        ModelConfig(
            name="qwen3-8b", paper_name="Qwen3-8B",
            d_model=192, n_layers=6, n_q_heads=6, n_kv_heads=3, d_head=32,
            d_ffn=768, decode_buckets=FULL_BUCKETS,
        ),
        ModelConfig(
            name="qwen3-30b-a3b", paper_name="Qwen3-30B-A3B",
            d_model=128, n_layers=4, n_q_heads=4, n_kv_heads=2, d_head=32,
            d_ffn=512, moe=MoeConfig(n_experts=8, top_k=2, d_expert=256),
        ),
        ModelConfig(
            name="llama-3.2-1b", paper_name="Llama-3.2-1B",
            d_model=96, n_layers=3, n_q_heads=4, n_kv_heads=4, d_head=24,
            d_ffn=384,
        ),
        ModelConfig(
            name="llama-3.2-3b", paper_name="Llama-3.2-3B",
            d_model=128, n_layers=4, n_q_heads=4, n_kv_heads=4, d_head=32,
            d_ffn=448,
        ),
        ModelConfig(
            name="gemma3-4b", paper_name="Gemma 3-4B",
            d_model=160, n_layers=4, n_q_heads=4, n_kv_heads=1, d_head=40,
            d_ffn=640, act="gelu",
        ),
        ModelConfig(
            name="nemotron-30b-a3b", paper_name="Nemotron-30B-A3B",
            d_model=160, n_layers=4, n_q_heads=4, n_kv_heads=2, d_head=40,
            d_ffn=576, moe=MoeConfig(n_experts=8, top_k=2, d_expert=288),
        ),
        ModelConfig(
            name="qwen3-vl-4b", paper_name="Qwen3-VL-4B",
            d_model=128, n_layers=4, n_q_heads=4, n_kv_heads=2, d_head=32,
            d_ffn=512, decode_buckets=(1, 2, 4, 8),
            vision=VisionConfig(d_model=128, n_layers=6, n_heads=4),
            prefill_buckets=(32, 128, 512),
        ),
        ModelConfig(
            name="qwen3-vl-8b", paper_name="Qwen3-VL-8B",
            d_model=192, n_layers=6, n_q_heads=6, n_kv_heads=3, d_head=32,
            d_ffn=768, decode_buckets=(1, 2, 4, 8),
            vision=VisionConfig(d_model=160, n_layers=8, n_heads=4),
            prefill_buckets=(32, 128, 512),
        ),
    ]
}

# VL prefill-with-embeddings buckets: visual tokens (<=256) + text.
EMBED_PREFILL_BUCKETS = (64, 192, 384, 640)

# Chunked-prefill buckets: chunk sizes the staged admission pipeline can
# feed per call (`prefill_chunk_c{C}` / `prefill_chunk_embeds_c{C}`).
# Small bucket for short catch-up suffixes, large for full-prompt chunks
# (the scheduler's default prefill_chunk_tokens is the largest bucket).
PREFILL_CHUNK_BUCKETS = (8, 32)

# Speculative-decoding verify buckets (`spec_chunk_c{C}` /
# `spec_chunk_paged_c{C}`): one dispatch scores C positions — the fed
# next-token plus up to C-1 draft tokens — and packs ALL C rows' logits
# for a single multi-position readback (`read_logits_chunk_c{C}`).
# Unlike PREFILL_CHUNK_BUCKETS these are capped by packed-logits
# capacity, not scheduler fairness: the dense entries pack C * vocab
# floats into the whole plane-0 region of the single slot
# (2 * n_kv_heads * s_max * d_head floats; smallest in the zoo is
# gemma3-4b at 2*1*640*40 = 51200, so C=16 -> 32768 fits every model,
# while C=32 -> 65536 would not).
SPEC_CHUNK_BUCKETS = (8, 16)

# Batched vision-encoder buckets (`vision_r{res}_b{B}`): one dispatch
# encodes up to B same-resolution images.  The serving scheduler picks
# the largest bucket <= its pending same-resolution count and falls
# back to the single-image entry for the remainder.
VISION_BATCH_BUCKETS = (2, 4, 8)
