"""Deterministic weight construction + the .umw export format.

Weights are generated from a seeded PRNG (seed = first 4 bytes of
SHA-256(model name)), quantized to the q4 nibble format for every large
GEMM, and exported to ``artifacts/<model>.umw`` for the Rust runtime.

.umw layout (little-endian):
    magic   4 bytes  b"UMW1"
    count   u32      number of tensors
    per tensor:
      name_len u16, name utf-8 bytes
      dtype    u8   (0 = f32, 1 = u8, 2 = i32)
      ndim     u8
      dims     u32 * ndim
      nbytes   u64
      data     raw bytes (row-major)
"""

from __future__ import annotations

import hashlib
import struct
from typing import Dict, List, Tuple

import numpy as np

from .configs import ModelConfig, Q4_GROUP
from .kernels.ref import pack_weights_q4

DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.uint8): 1, np.dtype(np.int32): 2}


def model_seed(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


def _init(rng: np.random.Generator, shape, scale=None) -> np.ndarray:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def build_weights(cfg: ModelConfig) -> Dict[str, np.ndarray]:
    """Construct the full weight dict for a model (text + vision tower).

    Quantized GEMMs appear as ``<name>.q4`` (uint8 packed) plus
    ``<name>.scales`` (f32); everything else is f32.
    """
    rng = np.random.default_rng(model_seed(cfg.name))
    w: Dict[str, np.ndarray] = {}

    def quantized(name: str, k: int, n: int, scale=None):
        dense = _init(rng, (k, n), scale)
        packed, scales, _ = pack_weights_q4(dense)
        w[f"{name}.q4"] = np.asarray(packed)
        w[f"{name}.scales"] = np.asarray(scales)

    d, dh = cfg.d_model, cfg.d_head
    w["emb"] = _init(rng, (cfg.vocab, d), scale=0.02)
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        w[p + "norm1"] = np.ones(d, np.float32)
        w[p + "norm2"] = np.ones(d, np.float32)
        quantized(p + "wq", d, cfg.d_q)
        quantized(p + "wk", d, cfg.d_kv)
        quantized(p + "wv", d, cfg.d_kv)
        quantized(p + "wo", cfg.d_q, d)
        if cfg.moe:
            m = cfg.moe
            w[p + "gate"] = _init(rng, (d, m.n_experts))
            w[p + "moe_w1"] = _init(rng, (m.n_experts, d, m.d_expert))
            w[p + "moe_w3"] = _init(rng, (m.n_experts, d, m.d_expert))
            w[p + "moe_w2"] = _init(rng, (m.n_experts, m.d_expert, d))
        else:
            quantized(p + "w1", d, cfg.d_ffn)
            quantized(p + "w3", d, cfg.d_ffn)
            quantized(p + "w2", cfg.d_ffn, d)
    w["norm_f"] = np.ones(d, np.float32)
    quantized("unembed", d, cfg.vocab)

    if cfg.vision:
        vc = cfg.vision
        dv = vc.d_model
        max_patches = max(vc.n_patches(r) for r in vc.resolutions)
        w["vis.patch_w"] = _init(rng, (vc.patch_dim, dv))
        w["vis.patch_b"] = np.zeros(dv, np.float32)
        w["vis.pos_emb"] = _init(rng, (max_patches, dv), scale=0.02)
        for l in range(vc.n_layers):
            p = f"vis.layers.{l}."
            w[p + "norm1"] = np.ones(dv, np.float32)
            w[p + "norm2"] = np.ones(dv, np.float32)
            w[p + "wqkv"] = _init(rng, (dv, 3 * dv))
            w[p + "wo"] = _init(rng, (dv, dv))
            w[p + "w1"] = _init(rng, (dv, 4 * dv))
            w[p + "w2"] = _init(rng, (4 * dv, dv))
        w["vis.norm_f"] = np.ones(dv, np.float32)
        w["vis.merge_w"] = _init(rng, (vc.merge * vc.merge * dv, d))
        w["vis.merge_b"] = np.zeros(d, np.float32)

    return w


def text_weight_order(cfg: ModelConfig) -> List[str]:
    """Deterministic argument order for text-model artifacts."""
    names = ["emb"]
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        names += [p + "norm1", p + "norm2"]
        for g in ("wq", "wk", "wv", "wo"):
            names += [p + g + ".q4", p + g + ".scales"]
        if cfg.moe:
            names += [p + "gate", p + "moe_w1", p + "moe_w3", p + "moe_w2"]
        else:
            for g in ("w1", "w3", "w2"):
                names += [p + g + ".q4", p + g + ".scales"]
    names += ["norm_f", "unembed.q4", "unembed.scales"]
    return names


def vision_weight_order(cfg: ModelConfig) -> List[str]:
    """Deterministic argument order for vision artifacts."""
    assert cfg.vision
    names = ["vis.patch_w", "vis.patch_b", "vis.pos_emb"]
    for l in range(cfg.vision.n_layers):
        p = f"vis.layers.{l}."
        names += [p + "norm1", p + "norm2", p + "wqkv", p + "wo", p + "w1", p + "w2"]
    names += ["vis.norm_f", "vis.merge_w", "vis.merge_b"]
    return names


def write_umw(path: str, weights: Dict[str, np.ndarray]) -> int:
    """Serialize a weight dict to the .umw container.  Returns bytes written."""
    blob = bytearray()
    blob += b"UMW1"
    blob += struct.pack("<I", len(weights))
    for name, arr in weights.items():
        arr = np.ascontiguousarray(arr)
        code = DTYPE_CODES[arr.dtype]
        nb = arr.nbytes
        name_b = name.encode()
        blob += struct.pack("<H", len(name_b)) + name_b
        blob += struct.pack("<BB", code, arr.ndim)
        blob += struct.pack(f"<{arr.ndim}I", *arr.shape)
        blob += struct.pack("<Q", nb)
        blob += arr.tobytes()
    with open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def read_umw(path: str) -> Dict[str, np.ndarray]:
    """Parse a .umw container (python-side round-trip check)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"UMW1", "bad magic"
    (count,) = struct.unpack_from("<I", data, 4)
    off = 8
    out: Dict[str, np.ndarray] = {}
    rev = {v: k for k, v in DTYPE_CODES.items()}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off : off + nlen].decode()
        off += nlen
        code, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        (nb,) = struct.unpack_from("<Q", data, off)
        off += 8
        arr = np.frombuffer(data[off : off + nb], dtype=rev[code]).reshape(dims)
        off += nb
        out[name] = arr
    return out
