"""L1 Pallas kernel: ViT patch embedding (patchify GEMM + bias).

The vision encoder's first layer projects flattened pixel patches into
the transformer width.  On the paper's workloads this runs once per
*distinct* image (the whole point of content-based caching is to skip
it on repeats), over up to 1024 patches at 1024x1024 input - the
largest single GEMM in the vision tower, so it gets the Pallas
treatment alongside attention and the quantized GEMMs.

TPU mapping: grid over patch tiles; each instance loads a [TP, C] pixel
tile (C = 3*32*32 = 3072 floats = 12 KiB/patch-row) and the shared
[C, D] projection into VMEM and issues one MXU contraction plus a VPU
bias add.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _patch_embed_kernel(p_ref, w_ref, b_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)   # [TP, C]
    w = w_ref[...]                        # [C, D]
    b = b_ref[...]                        # [D]
    o_ref[...] = jnp.dot(p, w, preferred_element_type=jnp.float32) + b[None, :]


def patch_embed(patches, w, b, *, block_p=None, interpret=True):
    """Patch embedding.  Same contract as ``ref.patch_embed_ref``.

    Args:
      patches: [P, C] flattened patches.
      w:       [C, D] projection.
      b:       [D] bias.
      block_p: patch-tile size (default min(P, 64); must divide P).
      interpret: lower to plain HLO for CPU PJRT.

    Returns:
      [P, D] f32 embeddings.
    """
    p, c = patches.shape
    d = w.shape[1]
    bp = block_p or min(p, 64)
    assert p % bp == 0, (p, bp)

    return pl.pallas_call(
        _patch_embed_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp, c), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, d), jnp.float32),
        interpret=interpret,
    )(patches, w, b)
