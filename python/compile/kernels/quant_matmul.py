"""L1 Pallas kernel: 4-bit (nibble-packed) dequant matmul.

The paper runs every model in 4-bit quantization (Q4_K_M for llama.cpp,
4-bit for MLX); the corresponding hot-spot on the MLX side is the fused
dequantize-then-GEMM kernel.  This kernel reproduces it: weights are
stored packed two-per-byte along K with per-group (group=32) f32 scales,
and dequantization happens in-register immediately before the MXU
contraction, so the full-precision weight matrix never materialises in
HBM.

TPU mapping: grid tiles over (M, N); each instance loads an [K/2, TN]
packed tile + [K/32, TN] scales into VMEM, expands nibbles with VPU
bit-ops, scales, and issues a [TM, K] x [K, TN] MXU contraction with f32
accumulation.  VMEM per instance at our sizes (K<=1152, TN=128):
packed 1152/2*128 = 72 KiB + x tile + f32 accumulator - well under
budget, so K is not tiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, *, group_size):
    x = x_ref[...].astype(jnp.float32)            # [TM, K]
    packed = w_ref[...]                           # [K//2, TN] uint8
    scales = s_ref[...].astype(jnp.float32)       # [K//group, TN]

    k2, tn = packed.shape
    k = k2 * 2
    low = (packed & 0xF).astype(jnp.int32) - 8    # even k
    high = (packed >> 4).astype(jnp.int32) - 8    # odd k
    # Interleave: w[2i] = low[i], w[2i+1] = high[i].
    w = jnp.stack([low, high], axis=1).reshape(k, tn).astype(jnp.float32)
    w = w * jnp.repeat(scales, group_size, axis=0)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


def quant_matmul(x, w_packed, scales, group_size=32, *, block_m=None, block_n=None,
                 interpret=True):
    """4-bit dequant matmul.  Same contract as ``ref.quant_matmul_ref``.

    Args:
      x:        [M, K] f32 activations.
      w_packed: [K//2, N] uint8 nibble-packed weights (low nibble = even k).
      scales:   [K//group_size, N] f32 per-group scales.
      group_size: K-elements per scale group (weights packed with 32).
      block_m/block_n: grid tile sizes (default: whole M, N tiled by 128).
      interpret: lower to plain HLO for CPU PJRT.

    Returns:
      [M, N] f32.
    """
    m, k = x.shape
    k2, n = w_packed.shape
    assert k2 * 2 == k, (k, k2)
    assert k % group_size == 0
    bm = block_m or m
    if block_n is None:
        # Largest tile <= 128 that divides N.
        bn = next(t for t in range(min(n, 128), 0, -1) if n % t == 0)
    else:
        bn = block_n
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    kernel = functools.partial(_quant_matmul_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k // 2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k // group_size, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scales)
