"""L1 Pallas kernel: fused single-step (decode) attention with GQA.

This is the serving hot-spot: every generated token, for every active
sequence in the batch, attends over its padded KV arena slot.  The MLX
original gets this fusion from lazy evaluation; here it is written
explicitly as a Pallas kernel so the HBM->VMEM schedule is under our
control on a real TPU, and lowers (``interpret=True``) into plain HLO
for the CPU PJRT runtime used in this reproduction.

TPU mapping (see DESIGN.md §Hardware-Adaptation):

* grid = (Hq,): one program instance per query head, processing the
  WHOLE batch tile for that head.  VMEM per instance: K + V rows for
  all slots = 2 x B x S_max x Dh x 4B (B=16, S=640, Dh<=48 -> ~3.9 MiB)
  plus the [B, Dh] query tile — inside the ~16 MiB VMEM budget.  For
  longer arenas the natural extension is a second grid axis over KV
  blocks with an online softmax accumulator.
* Batching across slots inside one program keeps the grid size
  independent of B.  This matters twice: on TPU it turns the per-slot
  matvecs into [B,Dh]x[B,S,Dh] batched contractions the MXU can tile;
  under interpret-mode CPU lowering it keeps the emulation loop at Hq
  iterations instead of B*Hq (the B-proportional grid made interpreted
  decode quadratic in batch size — EXPERIMENTS.md §Perf).
* Masking and softmax are VPU element-wise ops on the [B, S] tile;
  f32 accumulation throughout (paper models are 4-bit quantized for
  weights; attention state stays f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, scale, s_max):
    """One query-head tile over the whole batch:
    q [B, Dh], K/V [B, S, Dh] -> out [B, Dh]."""
    q = q_ref[:, 0, :].astype(jnp.float32)      # [B, Dh]
    k = k_ref[:, 0].astype(jnp.float32)         # [B, S, Dh]
    v = v_ref[:, 0].astype(jnp.float32)         # [B, S, Dh]
    lengths = len_ref[...]                      # [B]

    # [B, S] logits: batched matvec (MXU-tileable on TPU).
    logits = jnp.einsum("bd,bsd->bs", q, k) * scale
    mask = jax.lax.iota(jnp.int32, s_max)[None, :] < lengths[:, None]
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bs,bsd->bd", p / denom, v)          # [B, Dh]
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, interpret=True):
    """Fused decode attention.  Same contract as ``ref.decode_attention_ref``.

    Args:
      q:        [B, Hq, Dh] current-token queries.
      k_cache:  [B, Hkv, S, Dh] padded key arena.
      v_cache:  [B, Hkv, S, Dh] padded value arena.
      lengths:  [B] int32 valid lengths.
      interpret: lower to plain HLO (required for CPU PJRT; see module doc).

    Returns:
      [B, Hq, Dh] attention output, dtype of ``q``.
    """
    b, hq, dh = q.shape
    _, hkv, s, _ = k_cache.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_decode_attn_kernel, scale=scale, s_max=s)
    return pl.pallas_call(
        kernel,
        grid=(hq,),
        in_specs=[
            pl.BlockSpec((b,), lambda h: (0,)),                     # lengths
            pl.BlockSpec((b, 1, dh), lambda h: (0, h, 0)),          # q head tile
            pl.BlockSpec((b, 1, s, dh), lambda h: (0, h // group, 0, 0)),  # K
            pl.BlockSpec((b, 1, s, dh), lambda h: (0, h // group, 0, 0)),  # V
        ],
        out_specs=pl.BlockSpec((b, 1, dh), lambda h: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
