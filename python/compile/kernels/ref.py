"""Pure-jnp reference oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated
shape/dtype sweeps; the kernels are only trusted through that gate.
"""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-step (decode) attention against a padded KV cache.

    Args:
      q:        [B, Hq, Dh]   query for the current token.
      k_cache:  [B, Hkv, S, Dh] padded key cache.
      v_cache:  [B, Hkv, S, Dh] padded value cache.
      lengths:  [B] int32, number of valid positions per sequence
                (entries at >= length are padding and must not attend).

    Returns:
      [B, Hq, Dh] attention output.  Grouped-query attention: query head
      h reads KV head ``h // (Hq // Hkv)``.
    """
    b, hq, dh = q.shape
    hkv = k_cache.shape[1]
    s = k_cache.shape[2]
    group = hq // hkv
    # Expand KV heads to query heads.
    k = jnp.repeat(k_cache, group, axis=1)  # [B, Hq, S, Dh]
    v = jnp.repeat(v_cache, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs * mask
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhs,bhsd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def quant_matmul_ref(x, w_packed, scales, group_size):
    """4-bit (nibble-packed) dequant matmul reference.

    Args:
      x:        [M, K] activations (f32).
      w_packed: [K // 2, N] uint8; each byte holds two 4-bit weights
                along K: low nibble = even k, high nibble = odd k.
      scales:   [K // group_size, N] f32 per-group scales.
      group_size: ints of K per scale group.

    Weights decode as ``(nibble - 8) * scale`` (symmetric 4-bit).

    Returns:
      [M, N] f32 = x @ dequant(w).
    """
    kk2, n = w_packed.shape
    k = kk2 * 2
    low = (w_packed & 0xF).astype(jnp.int32) - 8   # even k
    high = (w_packed >> 4).astype(jnp.int32) - 8   # odd k
    w = jnp.zeros((k, n), jnp.int32)
    w = w.at[0::2].set(low)
    w = w.at[1::2].set(high)
    groups = jnp.repeat(scales, group_size, axis=0)  # [K, N]
    w_deq = w.astype(jnp.float32) * groups
    return x @ w_deq


def patch_embed_ref(patches, w, b):
    """ViT patch-embedding reference: flat patches → embeddings.

    Args:
      patches: [P, C] flattened pixel patches (C = 3 * patch * patch).
      w:       [C, D] projection.
      b:       [D] bias.
    Returns:
      [P, D] embeddings.
    """
    return patches.astype(jnp.float32) @ w + b


def pack_weights_q4(w):
    """Quantize an f32 [K, N] matrix to the nibble-packed q4 format.

    Returns (w_packed [K//2, N] uint8, scales [K//group, N] f32, group).
    Group size is fixed at 32 (K must be a multiple of 64).
    """
    import numpy as np

    k, n = w.shape
    group = 32
    assert k % (2 * group) == 0 or k % group == 0 and k % 2 == 0, (k, n)
    wg = np.asarray(w, np.float32).reshape(k // group, group, n)
    scales = np.abs(wg).max(axis=1) / 7.0  # [K//group, N]
    scales = np.maximum(scales, 1e-8)
    q = np.clip(np.round(wg / scales[:, None, :]), -8, 7).astype(np.int32) + 8
    q = q.reshape(k, n)
    packed = (q[0::2] | (q[1::2] << 4)).astype(np.uint8)  # [K//2, N]
    return jnp.asarray(packed), jnp.asarray(scales, jnp.float32), group
