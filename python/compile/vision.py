"""L2: the ViT-sim vision encoder (multimodal path).

Reproduces the cost structure of the paper's Qwen3-VL vision tower: a
patch-embedding GEMM (L1 Pallas kernel), full self-attention over the
patch grid (quadratic in resolution — this is why 1024x1024 encodes are
expensive and why content-based caching pays), and a 2x2 spatial merge
that projects into the text model's width.

One artifact is lowered per supported resolution; the Rust multimodal
pipeline patchifies decoded RGB on the host (a reshape, no compute) and
feeds [P, 3*patch*patch] f32 patches.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.patch_embed import patch_embed
from .model import W, rmsnorm
from .weights import vision_weight_order


def vision_encode_fn(cfg: ModelConfig, patches, *weights):
    """Encode one image's patches into text-space visual embeddings.

    Args:
      patches: [P, 3*patch*patch] f32 flattened patches, P = grid**2.
      weights: flat tuple per vision_weight_order.

    Returns:
      [T, d_text] f32 visual tokens, T = ceil(grid/merge)**2.
    """
    vc = cfg.vision
    assert vc is not None
    w = W(vision_weight_order(cfg), weights)
    p = patches.shape[0]
    g = int(round(p ** 0.5))
    assert g * g == p, (p, g)
    dv = vc.d_model

    x = patch_embed(patches, w["vis.patch_w"], w["vis.patch_b"],
                    block_p=min(p, 64) if p % min(p, 64) == 0 else p)
    x = x + w["vis.pos_emb"][:p]

    scale = 1.0 / jnp.sqrt(jnp.asarray(dv // vc.n_heads, jnp.float32))
    for l in range(vc.n_layers):
        pre = f"vis.layers.{l}."
        h = rmsnorm(x, w[pre + "norm1"])
        qkv = h @ w[pre + "wqkv"]                                # [P, 3dv]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = dv // vc.n_heads
        q = q.reshape(p, vc.n_heads, hd)
        k = k.reshape(p, vc.n_heads, hd)
        v = v.reshape(p, vc.n_heads, hd)
        logits = jnp.einsum("qhd,khd->hqk", q, k) * scale
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", probs, v).reshape(p, dv)
        x = x + attn @ w[pre + "wo"]
        h2 = rmsnorm(x, w[pre + "norm2"])
        x = x + jax.nn.gelu(h2 @ w[pre + "w1"]) @ w[pre + "w2"]

    x = rmsnorm(x, w["vis.norm_f"])

    # 2x2 spatial merge (pad odd grids), then project to text width.
    m = vc.merge
    gm = (g + m - 1) // m
    pad = gm * m - g
    grid = x.reshape(g, g, dv)
    if pad:
        grid = jnp.pad(grid, ((0, pad), (0, pad), (0, 0)))
    merged = grid.reshape(gm, m, gm, m, dv).transpose(0, 2, 1, 3, 4)
    merged = merged.reshape(gm * gm, m * m * dv)
    return merged @ w["vis.merge_w"] + w["vis.merge_b"]          # [T, d_text]


def vision_encode_batch_fn(cfg: ModelConfig, patches, *weights):
    """Encode a batch of same-resolution images in one dispatch.

    Args:
      patches: [B, P, 3*patch*patch] f32 flattened patches.
      weights: flat tuple per vision_weight_order.

    Returns:
      [B, T, d_text] f32 visual tokens.

    Deliberately an UNROLLED stack of per-image ``vision_encode_fn``
    graphs rather than a vmap: each image's subgraph is structurally
    identical to the single-image ``vision_r{res}`` entry, so a batched
    encode is bit-exact with B single encodes (verified by
    test_model.test_batched_vision_encode_is_bitexact).  vmap reorders
    the batched contractions enough to shift results by ~1e-6, which
    would fork the embedding-cache contents (and their recorded
    fingerprints) by whichever batch size happened to encode an image
    first.  The serving scheduler batches at most 8 images per
    dispatch, so the unrolled graph stays small (~B x 44 kB of HLO).
    """
    return jnp.stack(
        [vision_encode_fn(cfg, patches[i], *weights) for i in range(patches.shape[0])]
    )


def vision_encode_ref(cfg: ModelConfig, patches, weights_dict):
    """Dict-keyed convenience wrapper for tests."""
    order = vision_weight_order(cfg)
    return vision_encode_fn(cfg, patches, *[jnp.asarray(weights_dict[n]) for n in order])
