"""Build-time substrate tests: BPE tokenizer training/encode/decode and
the .umw weight container."""

import os
import tempfile

import numpy as np
import pytest

# Property sweeps need hypothesis; CI installs it, but container images
# without it should still run the rest of the suite.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import tokenizer_train as T
from compile.configs import MODELS
from compile.weights import build_weights, read_umw, text_weight_order, vision_weight_order, write_umw


# ------------------------------------------------------------- tokenizer

MERGES = T.train_bpe(T.CORPUS, 2048)


def test_training_produces_merges():
    assert len(MERGES) > 100, "corpus should support >100 merges"
    # All merge ids valid and self-consistent.
    for r, (a, b) in enumerate(MERGES):
        assert a < 260 + r and b < 260 + r
        assert a >= T.N_SPECIAL and b >= T.N_SPECIAL


@settings(max_examples=50, deadline=None)
@given(st.text(min_size=0, max_size=200))
def test_encode_decode_roundtrip(text):
    ids = T.encode(text, MERGES)
    got = T.decode_bytes(ids, MERGES).decode("utf-8")
    assert got == text


def test_corpus_words_compress():
    ids = T.encode("continuous batching throughput scheduler", MERGES)
    n_bytes = len("continuous batching throughput scheduler".encode())
    assert len(ids) < n_bytes / 2


def test_export_format(tmp_path):
    path = str(tmp_path / "tok.json")
    spec = T.export(path, 2048)
    assert os.path.exists(path)
    assert spec["vocab_size"] == 2048
    assert spec["specials"]["img"] == 3
    import json

    reloaded = json.load(open(path))
    assert reloaded["merges"] == [list(m) for m in spec["merges"]] or reloaded["merges"] == spec["merges"]


# ------------------------------------------------------------- weights

def test_umw_roundtrip(tmp_path):
    w = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.arange(8, dtype=np.uint8).reshape(2, 2, 2),
        "c": np.asarray([-1, 2], np.int32),
    }
    path = str(tmp_path / "w.umw")
    write_umw(path, w)
    back = read_umw(path)
    assert set(back) == set(w)
    for k in w:
        np.testing.assert_array_equal(back[k], w[k])
        assert back[k].dtype == w[k].dtype


def test_weights_are_deterministic():
    a = build_weights(MODELS["qwen3-0.6b"])
    b = build_weights(MODELS["qwen3-0.6b"])
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # Different model name -> different weights.
    c = build_weights(MODELS["qwen3-4b"])
    assert a["emb"].shape != c["emb"].shape or not np.array_equal(a["emb"], c["emb"])


@pytest.mark.parametrize("name", list(MODELS))
def test_weight_order_covers_exactly(name):
    """Every name in the arg order exists; text+vision order is complete
    and duplicate-free."""
    cfg = MODELS[name]
    w = build_weights(cfg)
    order = text_weight_order(cfg)
    if cfg.vision:
        order = order + vision_weight_order(cfg)
    assert len(order) == len(set(order)), "duplicate weight names"
    for n in order:
        assert n in w, f"missing {n}"
    # Conversely, no orphan tensors.
    assert set(order) == set(w), set(w) ^ set(order)


def test_q4_tensors_have_scale_pairs():
    w = build_weights(MODELS["qwen3-0.6b"])
    for k in w:
        if k.endswith(".q4"):
            base = k[: -len(".q4")]
            assert base + ".scales" in w
            assert w[k].dtype == np.uint8
            # Packed K is half of scales' group-expanded K.
            assert w[k].shape[0] * 2 == w[base + ".scales"].shape[0] * 32
