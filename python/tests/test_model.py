"""L2 model invariants: causality, KV-incrementality, mailbox layout,
MoE routing, vision encoder shape/merge behaviour."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile import vision as V
from compile.configs import MODELS
from compile.weights import build_weights, text_weight_order

CFG = MODELS["qwen3-0.6b"]
W = build_weights(CFG)
ARRS = [jnp.asarray(W[n]) for n in text_weight_order(CFG)]


def prefill(cfg, arrs, prompt, bucket=32):
    toks = jnp.zeros(bucket, jnp.int32).at[: len(prompt)].set(jnp.asarray(prompt))
    return M.prefill_fn(cfg, toks, jnp.asarray(len(prompt), jnp.int32), *arrs)


def test_prefill_is_causal():
    """Changing a later prompt token must not change earlier logits...
    verified via the KV rows: K/V at position i depend only on tokens <= i."""
    p1 = [1, 10, 20, 30, 40]
    p2 = [1, 10, 20, 99, 77]  # differs from position 3 on
    kv1 = prefill(CFG, ARRS, p1)
    kv2 = prefill(CFG, ARRS, p2)
    # Layer planes 1..L, positions 0..2 must match exactly.
    a = np.asarray(kv1)[1:, :, :, :, :3, :]
    b = np.asarray(kv2)[1:, :, :, :, :3, :]
    np.testing.assert_allclose(a, b, rtol=0, atol=0)
    # And positions 3.. must differ.
    a3 = np.asarray(kv1)[1:, :, :, :, 3:5, :]
    b3 = np.asarray(kv2)[1:, :, :, :, 3:5, :]
    assert np.abs(a3 - b3).max() > 1e-6


def test_padding_tokens_do_not_affect_logits():
    """Same prompt in different prefill buckets -> same logits."""
    p = [1, 5, 9]
    kv32 = prefill(CFG, ARRS, p, bucket=32)
    kv128 = prefill(CFG, ARRS, p, bucket=128)
    l32 = M.read_logits_mailbox(CFG, kv32, 0)
    l128 = M.read_logits_mailbox(CFG, kv128, 0)
    np.testing.assert_allclose(l32, l128, rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_shifted():
    """prefill(P + t) logits == prefill(P) -> decode(t) logits."""
    p = [1, 10, 20, 30]
    kv_full = prefill(CFG, ARRS, p + [40])
    want = M.read_logits_mailbox(CFG, kv_full, 0)

    kv = prefill(CFG, ARRS, p)
    arena = M.inject_fn(CFG, jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32), kv,
                        jnp.asarray(0, jnp.int32))
    arena = M.decode_fn(CFG, jnp.asarray([40], jnp.int32), jnp.asarray([4], jnp.int32),
                        arena, *ARRS)
    got = M.read_logits_mailbox(CFG, arena, 0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_batched_decode_slots_are_independent():
    """Slot b's logits must not depend on what other slots contain."""
    p = [1, 7, 13]
    kv = prefill(CFG, ARRS, p)
    z = jnp.zeros(M.kv_arena_shape(CFG, 2), jnp.float32)
    arena = M.inject_fn(CFG, z, kv, jnp.asarray(0, jnp.int32))
    # Slot 1 holds a DIFFERENT sequence.
    kv_other = prefill(CFG, ARRS, [2, 50, 60, 70, 80])
    arena = M.inject_fn(CFG, arena, kv_other, jnp.asarray(1, jnp.int32))
    stepped = M.decode_fn(CFG, jnp.asarray([40, 41], jnp.int32),
                          jnp.asarray([3, 5], jnp.int32), arena, *ARRS)
    got0 = M.read_logits_mailbox(CFG, stepped, 0)

    # Reference: slot 0 alone in a b1 arena.
    arena1 = M.inject_fn(CFG, jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32), kv,
                         jnp.asarray(0, jnp.int32))
    arena1 = M.decode_fn(CFG, jnp.asarray([40], jnp.int32), jnp.asarray([3], jnp.int32),
                         arena1, *ARRS)
    want0 = M.read_logits_mailbox(CFG, arena1, 0)
    np.testing.assert_allclose(got0, want0, rtol=2e-4, atol=2e-4)


def test_extract_inject_roundtrip():
    kv = prefill(CFG, ARRS, [1, 11, 22])
    z = jnp.zeros(M.kv_arena_shape(CFG, 4), jnp.float32)
    arena = M.inject_fn(CFG, z, kv, jnp.asarray(2, jnp.int32))
    back = M.extract_fn(CFG, arena, jnp.asarray(2, jnp.int32))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kv))


def test_logits_mailbox_consistency():
    """read_logits_fn (the artifact) == read_logits_mailbox (the layout)."""
    kv = prefill(CFG, ARRS, [1, 3, 5, 7])
    via_fn = M.read_logits_fn(CFG, kv)
    via_layout = M.read_logits_mailbox(CFG, kv, 0)
    np.testing.assert_allclose(via_fn[0], via_layout, rtol=0, atol=0)
    assert via_fn.shape == (1, CFG.vocab)


def test_chunked_prefill_matches_full_prefill():
    """Feeding a prompt in chunks through prefill_chunk_fn must agree
    with one-shot prefill_fn on logits AND on every valid KV position."""
    p = [1, 10, 20, 30, 40, 50, 60, 70, 80, 90, 100, 110]
    kv_full = prefill(CFG, ARRS, p)

    kv = M.zeros_fn(CFG, 1)
    for start in range(0, len(p), 8):
        chunk = p[start : start + 8]
        toks = jnp.zeros(8, jnp.int32).at[: len(chunk)].set(jnp.asarray(chunk))
        kv = M.prefill_chunk_fn(
            CFG, toks, jnp.asarray(start, jnp.int32),
            jnp.asarray(len(chunk), jnp.int32), kv, *ARRS)

    np.testing.assert_allclose(
        M.read_logits_mailbox(CFG, kv, 0),
        M.read_logits_mailbox(CFG, kv_full, 0),
        rtol=2e-4, atol=2e-4,
    )
    a = np.asarray(kv)[1:, :, :, :, : len(p), :]
    b = np.asarray(kv_full)[1:, :, :, :, : len(p), :]
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_chunked_suffix_feed_matches_decode_feed():
    """The chunked catch-up invariant: extending a KV state by a suffix
    via ONE prefill_chunk_fn call must match feeding the suffix
    token-by-token through bucket-1 decode.  The same fused kernel runs
    in both paths, but XLA fuses [C, d] and [1, d] row blocks
    differently, so equality is within fp tolerance (empirically
    <2e-6 abs) with identical greedy argmax — the same batch-invariance
    contract the decode arena already relies on."""
    prefix = [1, 5, 9, 13]
    suffix = [17, 21, 25, 29, 33]
    kv = prefill(CFG, ARRS, prefix)

    # Path A: token-by-token decode on an injected arena, then extract.
    arena = M.inject_fn(CFG, jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32),
                        kv, jnp.asarray(0, jnp.int32))
    for i, t in enumerate(suffix):
        arena = M.decode_fn(CFG, jnp.asarray([t], jnp.int32),
                            jnp.asarray([len(prefix) + i], jnp.int32), arena, *ARRS)
    kv_a = M.extract_fn(CFG, arena, jnp.asarray(0, jnp.int32))

    # Path B: one chunk call on a copy of the same state.
    kv_b = M.inject_fn(CFG, jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32),
                       kv, jnp.asarray(0, jnp.int32))
    toks = jnp.zeros(8, jnp.int32).at[: len(suffix)].set(jnp.asarray(suffix))
    kv_b = M.prefill_chunk_fn(
        CFG, toks, jnp.asarray(len(prefix), jnp.int32),
        jnp.asarray(len(suffix), jnp.int32), kv_b, *ARRS)

    np.testing.assert_allclose(np.asarray(kv_a), np.asarray(kv_b),
                               rtol=2e-4, atol=2e-4)
    la = M.read_logits_mailbox(CFG, kv_a, 0)
    lb = M.read_logits_mailbox(CFG, kv_b, 0)
    assert int(jnp.argmax(la)) == int(jnp.argmax(lb))


def test_chunked_prefill_embeds_matches_token_chunks():
    """prefill_chunk_embeds_fn(emb[chunk]) == prefill_chunk_fn(chunk)."""
    prefix = [1, 3, 5]
    suffix = [7, 11, 15, 19]
    kv0 = prefill(CFG, ARRS, prefix)
    base = lambda: M.inject_fn(
        CFG, jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32), kv0,
        jnp.asarray(0, jnp.int32))
    toks = jnp.zeros(8, jnp.int32).at[: len(suffix)].set(jnp.asarray(suffix))
    kv_t = M.prefill_chunk_fn(
        CFG, toks, jnp.asarray(len(prefix), jnp.int32),
        jnp.asarray(len(suffix), jnp.int32), base(), *ARRS)
    emb = M.embed_lookup_fn(CFG, toks, *ARRS)
    kv_e = M.prefill_chunk_embeds_fn(
        CFG, emb, jnp.asarray(len(prefix), jnp.int32),
        jnp.asarray(len(suffix), jnp.int32), base(), *ARRS)
    np.testing.assert_allclose(np.asarray(kv_t), np.asarray(kv_e),
                               rtol=1e-6, atol=1e-6)


def test_zeros_fn_matches_arena_shape():
    for b in (1, 4):
        z = M.zeros_fn(CFG, b)
        assert z.shape == M.kv_arena_shape(CFG, b)
        assert not np.asarray(z).any()


def test_read_logits_one_matches_mailbox():
    kv = prefill(CFG, ARRS, [1, 2, 3, 4])
    z = jnp.zeros(M.kv_arena_shape(CFG, 4), jnp.float32)
    arena = M.inject_fn(CFG, z, kv, jnp.asarray(2, jnp.int32))
    got = M.read_logits_one_fn(CFG, arena, jnp.asarray(2, jnp.int32))
    want = M.read_logits_mailbox(CFG, arena, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)
    assert got.shape == (CFG.vocab,)


def test_moe_routing_uses_top2():
    """A MoE model's FFN output == manual dense mix of top-2 experts."""
    cfg = MODELS["qwen3-30b-a3b"]
    w = build_weights(cfg)
    h = jnp.asarray(np.random.default_rng(0).standard_normal((3, cfg.d_model)), jnp.float32)
    from compile.model import W as Binder, _ffn

    binder = Binder(text_weight_order(cfg), [jnp.asarray(w[n]) for n in text_weight_order(cfg)])
    got = _ffn(cfg, binder, "layers.0.", h)
    # Manual reference.
    gate = h @ w["layers.0.gate"]
    top = np.argsort(-np.asarray(gate), axis=-1)[:, : cfg.moe.top_k]
    want = np.zeros((3, cfg.d_model), np.float32)
    for n in range(3):
        logits = np.asarray(gate)[n, top[n]]
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        for e, p in zip(top[n], probs):
            a = np.asarray(h)[n] @ w["layers.0.moe_w1"][e]
            g = np.asarray(h)[n] @ w["layers.0.moe_w3"][e]
            act = a / (1 + np.exp(-a))  # silu
            want[n] += p * ((act * g) @ w["layers.0.moe_w2"][e])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("resolution", [224, 448])
def test_vision_encoder_shapes(resolution):
    cfg = MODELS["qwen3-vl-4b"]
    w = build_weights(cfg)
    p = cfg.vision.n_patches(resolution)
    patches = jnp.asarray(
        np.random.default_rng(1).standard_normal((p, cfg.vision.patch_dim)), jnp.float32)
    out = V.vision_encode_ref(cfg, patches, w)
    assert out.shape == (cfg.vision.n_visual_tokens(resolution), cfg.d_model)
    assert np.isfinite(np.asarray(out)).all()


def test_vision_encoder_is_content_sensitive():
    cfg = MODELS["qwen3-vl-4b"]
    w = build_weights(cfg)
    rng = np.random.default_rng(2)
    p1 = jnp.asarray(rng.standard_normal((49, cfg.vision.patch_dim)), jnp.float32)
    p2 = p1.at[0, 0].add(1.0)
    o1 = V.vision_encode_ref(cfg, p1, w)
    o2 = V.vision_encode_ref(cfg, p2, w)
    assert np.abs(np.asarray(o1) - np.asarray(o2)).max() > 1e-6


def test_batched_vision_encode_is_bitexact():
    """vision_encode_batch_fn == B independent vision_encode_fn calls,
    BIT-exactly.  The serving scheduler batches same-resolution encoder
    work through the `vision_r{res}_b{B}` entries; bit-exactness is
    what keeps the embedding cache (and the fingerprints recorded for
    "KV only" validation) independent of whichever batch size happened
    to encode an image first.  The unrolled-stack construction in
    vision.py exists precisely because vmap does NOT satisfy this."""
    import functools

    cfg = MODELS["qwen3-vl-4b"]
    w = build_weights(cfg)
    from compile.weights import vision_weight_order

    arrs = [jnp.asarray(w[n]) for n in vision_weight_order(cfg)]
    p = cfg.vision.n_patches(224)
    rng = np.random.default_rng(7)
    batch = jnp.asarray(
        rng.standard_normal((4, p, cfg.vision.patch_dim)), jnp.float32)

    single = jax.jit(functools.partial(V.vision_encode_fn, cfg))
    batched = jax.jit(functools.partial(V.vision_encode_batch_fn, cfg))
    want = np.stack([np.asarray(single(batch[i], *arrs)) for i in range(4)])
    got = np.asarray(batched(batch, *arrs))
    assert got.shape == (4, cfg.vision.n_visual_tokens(224), cfg.d_model)
    assert np.array_equal(got, want), (
        f"batched encode diverged from single encodes "
        f"(max abs diff {np.abs(got - want).max()})")


def test_prefill_embeds_equals_prefill_on_token_embeds():
    """prefill_embeds(emb[tokens]) == prefill(tokens) (the VL text path
    is the same trunk)."""
    p = [1, 4, 9, 16]
    cfg = MODELS["qwen3-vl-4b"]
    w = build_weights(cfg)
    arrs = [jnp.asarray(w[n]) for n in text_weight_order(cfg)]
    toks = jnp.zeros(64, jnp.int32).at[: len(p)].set(jnp.asarray(p))
    emb = M.embed_lookup_fn(cfg, toks, *arrs)
    kv_a = M.prefill_embeds_fn(cfg, emb, jnp.asarray(len(p), jnp.int32), *arrs)
    # prefill at bucket 32 (embeds bucket is 64; padding-invariance holds).
    toks32 = jnp.zeros(32, jnp.int32).at[: len(p)].set(jnp.asarray(p))
    kv_b = M.prefill_fn(cfg, toks32, jnp.asarray(len(p), jnp.int32), *arrs)
    np.testing.assert_allclose(
        M.read_logits_mailbox(cfg, kv_a, 0),
        M.read_logits_mailbox(cfg, kv_b, 0),
        rtol=2e-4, atol=2e-4,
    )
