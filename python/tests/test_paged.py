"""Paged-KV equivalence: the block-table entries must be *bit-exact*
against the dense slot-arena entries.

The paged gather materializes a [B, Hkv, s_max, Dh] cache view with the
same shape and the same valid contents as the dense arena row, and the
attention kernel masks positions >= len with -1e30 before any reduction,
so garbage in unallocated / stale pages cannot perturb a single output
bit.  These tests pin that contract at the L2 (jax) level; the Rust
scheduler equivalence tests pin it end-to-end.
"""

import numpy as np
import jax.numpy as jnp

from compile import model as M
from compile.configs import KV_PAGE_SIZE, MODELS

CFG = MODELS["qwen3-0.6b"]
NBLK = CFG.kv_blocks_per_seq()
POOL_PAGES = CFG.kv_pool_pages()

from compile.weights import build_weights, text_weight_order

W = build_weights(CFG)
ARRS = [jnp.asarray(W[n]) for n in text_weight_order(CFG)]


def prefill(prompt, bucket=32):
    toks = jnp.zeros(bucket, jnp.int32).at[: len(prompt)].set(jnp.asarray(prompt))
    return M.prefill_fn(CFG, toks, jnp.asarray(len(prompt), jnp.int32), *ARRS)


def i32(x):
    return jnp.asarray(x, jnp.int32)


def seq_tables(pages):
    """Block table for one sequence: its pages, padded with page 0."""
    t = [0] * NBLK
    for j, p in enumerate(pages):
        t[j] = p
    return i32(t)


def test_mailbox_region_covers_vocab_for_every_model():
    for cfg in MODELS.values():
        region = cfg.n_kv_heads * KV_PAGE_SIZE * cfg.d_head
        assert region >= cfg.vocab, cfg.name
        assert cfg.logits_rows() <= cfg.n_kv_heads * KV_PAGE_SIZE, cfg.name
        assert cfg.s_max % KV_PAGE_SIZE == 0, cfg.name


def test_adopt_then_read_logits_page_roundtrip():
    kv_one = prefill([1, 10, 20, 30])
    want = M.read_logits_mailbox(CFG, kv_one, 0)
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, kv_one, seq_tables([3]), i32(7))
    got = M.read_logits_page_fn(CFG, pool, i32(7))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Adopted K/V lands on the sequence's pages bit-exactly.
    kp = np.asarray(pool)[1:, :, 3, :, :4, :]
    ref = np.asarray(kv_one)[1:, :, 0, :, :4, :]
    np.testing.assert_array_equal(kp, ref)


def test_decode_paged_bitwise_matches_dense():
    """N greedy steps: paged pool vs dense arena, logits bit-identical."""
    prompts = [[1, 10, 20, 30], [2, 50, 60]]
    b = 2
    arena = jnp.zeros(M.kv_arena_shape(CFG, b), jnp.float32)
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    tables, mailbox, pos = [], [], []
    for slot, p in enumerate(prompts):
        kv_one = prefill(p)
        arena = M.inject_fn(CFG, arena, kv_one, i32(slot))
        pages = [10 + slot * 16]           # one page covers len<=64
        pool = M.adopt_paged_fn(CFG, pool, kv_one, seq_tables(pages),
                                i32(100 + slot))
        tables.append(seq_tables(pages))
        mailbox.append(100 + slot)
        pos.append(len(p))
    tables = jnp.stack(tables)
    mailbox = i32(mailbox)

    for _ in range(5):
        toks = []
        for slot in range(b):
            ld = np.asarray(M.read_logits_mailbox(CFG, arena, slot))
            lp = np.asarray(M.read_logits_page_fn(CFG, pool, i32(mailbox[slot])))
            np.testing.assert_array_equal(lp, ld)
            toks.append(int(ld.argmax()))
        arena = M.decode_fn(CFG, i32(toks), i32(pos), arena, *ARRS)
        pool = M.decode_paged_fn(CFG, i32(toks), i32(pos), tables, mailbox,
                                 pool, *ARRS)
        pos = [p + 1 for p in pos]


def test_decode_paged_preserves_other_mailbox_pages():
    """The paged mailbox write is a scatter, not a plane zero-fill:
    pages belonging to staged sequences must survive a decode step."""
    kv_one = prefill([1, 10, 20, 30])
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, kv_one, seq_tables([3]), i32(7))
    bystander = np.asarray(M.read_logits_page_fn(CFG, pool, i32(7)))

    kv2 = prefill([2, 50, 60])
    pool = M.adopt_paged_fn(CFG, pool, kv2, seq_tables([5]), i32(9))
    pool = M.decode_paged_fn(CFG, i32([70]), i32([3]),
                             seq_tables([5])[None], i32([9]), pool, *ARRS)
    after = np.asarray(M.read_logits_page_fn(CFG, pool, i32(7)))
    np.testing.assert_array_equal(after, bystander)


def test_chunked_prefill_paged_bitwise_matches_dense_chunks():
    """Feeding the same chunk schedule into pages vs a kv_one yields
    bit-identical K/V content and mailbox logits."""
    prompt = [1, 9, 17, 25, 33, 41, 49, 57, 65, 73, 81, 89]
    c = 8
    kv_one = jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32)
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    tables = seq_tables([4, 5])
    for start in range(0, len(prompt), c):
        chunk = prompt[start : start + c]
        toks = jnp.zeros(c, jnp.int32).at[: len(chunk)].set(i32(chunk))
        kv_one = M.prefill_chunk_fn(CFG, toks, i32(start), i32(len(chunk)),
                                    kv_one, *ARRS)
        pool = M.prefill_chunk_paged_fn(CFG, toks, i32(start), i32(len(chunk)),
                                        tables, i32(11), pool, *ARRS)
    ld = np.asarray(M.read_logits_mailbox(CFG, kv_one, 0))
    lp = np.asarray(M.read_logits_page_fn(CFG, pool, i32(11)))
    np.testing.assert_array_equal(lp, ld)
    # K/V planes: kv_one positions 0..len-1 == page content.
    n = len(prompt)
    dense = np.asarray(kv_one)[1:, :, 0, :, :n, :]
    kp = np.asarray(pool)[1:, :, 4, :, :, :]          # first page, 64 pos
    np.testing.assert_array_equal(kp[:, :, :, :n, :], dense)


def test_copy_page_clones_one_page_everywhere():
    kv_one = prefill([1, 10, 20, 30])
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, kv_one, seq_tables([3]), i32(7))
    before = np.asarray(pool)
    pool2 = M.copy_page_fn(CFG, pool, i32(3), i32(20))
    after = np.asarray(pool2)
    np.testing.assert_array_equal(after[:, :, 20], before[:, :, 3])
    # Everything except the destination page is untouched.
    mask = np.ones(after.shape[2], bool)
    mask[20] = False
    np.testing.assert_array_equal(after[:, :, mask], before[:, :, mask])


def test_decode_paged_cow_divergence():
    """Two sequences sharing a full prefix page diverge bit-exactly: the
    shared page is read-only (both write their new token into their own
    second page), matching independent dense slots."""
    prompt = list(range(1, 65))            # exactly one full page
    kv_one = prefill(prompt, bucket=128)
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    # Both sequences' block tables point at shared page 6; their second
    # (divergence) blocks are private pages 7 and 8.
    pool = M.adopt_paged_fn(CFG, pool, kv_one, seq_tables([6]), i32(30))
    t0, t1 = seq_tables([6, 7]), seq_tables([6, 8])
    shared_before = np.asarray(pool)[:, :, 6].copy()

    # Dense reference: two independent slots, same prefix.
    arena = jnp.zeros(M.kv_arena_shape(CFG, 2), jnp.float32)
    arena = M.inject_fn(CFG, arena, kv_one, i32(0))
    arena = M.inject_fn(CFG, arena, kv_one, i32(1))

    arena = M.decode_fn(CFG, i32([70, 71]), i32([64, 64]), arena, *ARRS)
    pool = M.decode_paged_fn(CFG, i32([70, 71]), i32([64, 64]),
                             jnp.stack([t0, t1]), i32([31, 32]), pool, *ARRS)
    for slot, mb in ((0, 31), (1, 32)):
        ld = np.asarray(M.read_logits_mailbox(CFG, arena, slot))
        lp = np.asarray(M.read_logits_page_fn(CFG, pool, i32(mb)))
        np.testing.assert_array_equal(lp, ld)
    # The shared page was not written.
    np.testing.assert_array_equal(np.asarray(pool)[:, :, 6], shared_before)
