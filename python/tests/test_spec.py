"""Speculative-verify parity: the multi-position readback entries
(`spec_chunk_c{C}` / `read_logits_chunk_c{C}` and their paged twins)
must return, for every chunk row i, logits fp-equivalent — with
identical greedy argmax — to the tokenwise decode step that fed the
same prefix.  That contract is what makes chunk-verify an EXACT greedy
speculative-decoding verifier: accepting the longest matched argmax
prefix can never change the emitted byte stream.

Also pinned here: dense-vs-paged bit-identity of the packed readback,
K/V side-effect equivalence with the plain prefill_chunk entries, and
scratch-page isolation (a paged spec dispatch must not disturb other
sequences' pages or mailboxes).
"""

import numpy as np
import jax.numpy as jnp

from compile import model as M
from compile.configs import KV_PAGE_SIZE, MODELS, SPEC_CHUNK_BUCKETS
from compile.weights import build_weights, text_weight_order

CFG = MODELS["qwen3-0.6b"]
NBLK = CFG.kv_blocks_per_seq()

W = build_weights(CFG)
ARRS = [jnp.asarray(W[n]) for n in text_weight_order(CFG)]


def i32(x):
    return jnp.asarray(x, jnp.int32)


def prefill(prompt, bucket=32):
    toks = jnp.zeros(bucket, jnp.int32).at[: len(prompt)].set(i32(prompt))
    return M.prefill_fn(CFG, toks, i32(len(prompt)), *ARRS)


def seq_tables(pages):
    t = [0] * NBLK
    for j, p in enumerate(pages):
        t[j] = p
    return i32(t)


def spec_tokens(chunk, c):
    return jnp.zeros(c, jnp.int32).at[: len(chunk)].set(i32(chunk))


def tokenwise_rows(prompt, chunk):
    """Reference: feed `chunk` one token at a time through decode_fn,
    collecting the mailbox logits after each feed."""
    kv_one = prefill(prompt)
    arena = jnp.zeros(M.kv_arena_shape(CFG, 1), jnp.float32)
    arena = M.inject_fn(CFG, arena, kv_one, i32(0))
    rows, pos = [], len(prompt)
    for t in chunk:
        arena = M.decode_fn(CFG, i32([t]), i32([pos]), arena, *ARRS)
        rows.append(np.asarray(M.read_logits_mailbox(CFG, arena, 0)))
        pos += 1
    return np.stack(rows)


def test_spec_buckets_fit_every_model():
    for cfg in MODELS.values():
        dense_region = 2 * cfg.n_kv_heads * cfg.s_max * cfg.d_head
        for c in SPEC_CHUNK_BUCKETS:
            assert c * cfg.vocab <= dense_region, (cfg.name, c)
            m = cfg.spec_scratch_pages(c)
            per = ((cfg.n_layers + 1) * 2 * cfg.n_kv_heads
                   * KV_PAGE_SIZE * cfg.d_head)
            assert c * cfg.vocab <= m * per, (cfg.name, c)
            # Scratch stays a tiny fraction of the lowered pool.
            assert m <= 4, (cfg.name, c, m)


def test_spec_chunk_rows_match_tokenwise_decode():
    """Row i of the packed readback == logits after feeding chunk[0..=i]
    tokenwise: fp-close and argmax-identical (the greedy-exactness
    contract the Rust accept loop relies on)."""
    prompt = [1, 10, 20, 30]
    chunk = [40, 3, 17, 99, 5]            # next_token + 4 drafts
    c = 8
    ref = tokenwise_rows(prompt, chunk)

    kv_one = prefill(prompt)
    kv_one = M.spec_chunk_fn(CFG, spec_tokens(chunk, c), i32(len(prompt)),
                             i32(len(chunk)), kv_one, *ARRS)
    got = np.asarray(M.read_logits_chunk_fn(CFG, c, kv_one))[: len(chunk)]

    np.testing.assert_allclose(got, ref, atol=2e-4)
    np.testing.assert_array_equal(got.argmax(-1), ref.argmax(-1))


def test_spec_chunk_paged_bitwise_matches_dense():
    """The paged spec entry packs byte-identical logits to the dense
    one, and its K/V page writes match prefill_chunk_paged's."""
    prompt = [2, 50, 60]
    chunk = [70, 8, 8, 8]
    c = 8
    m = CFG.spec_scratch_pages(c)
    scratch = i32(list(range(15, 15 + m)))
    tables = seq_tables([4])

    kv_one = prefill(prompt)
    dense = M.spec_chunk_fn(CFG, spec_tokens(chunk, c), i32(len(prompt)),
                            i32(len(chunk)), kv_one, *ARRS)
    want = np.asarray(M.read_logits_chunk_fn(CFG, c, dense))

    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, prefill(prompt), tables, i32(9))
    pool = M.spec_chunk_paged_fn(CFG, spec_tokens(chunk, c), i32(len(prompt)),
                                 i32(len(chunk)), tables, scratch, pool, *ARRS)
    got = np.asarray(M.read_logits_chunk_paged_fn(CFG, c, pool, scratch))
    np.testing.assert_array_equal(got, want)

    # K/V side effects == plain chunked prefill of the same tokens.
    pool2 = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool2 = M.adopt_paged_fn(CFG, pool2, prefill(prompt), tables, i32(9))
    pool2 = M.prefill_chunk_paged_fn(CFG, spec_tokens(chunk, c),
                                     i32(len(prompt)), i32(len(chunk)),
                                     tables, i32(9), pool2, *ARRS)
    n = len(prompt) + len(chunk)
    np.testing.assert_array_equal(
        np.asarray(pool)[1:, :, 4, :, :n, :],
        np.asarray(pool2)[1:, :, 4, :, :n, :])


def test_spec_chunk_paged_preserves_bystanders():
    """A spec dispatch touches only the target sequence's pages and its
    scratch pages: other sequences' K/V and mailbox logits survive
    bit-exactly (the invariant that lets speculative lanes interleave
    with staged prefills on one pool)."""
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, prefill([1, 10, 20, 30]),
                            seq_tables([3]), i32(7))
    bystander_kv = np.asarray(pool)[:, :, 3].copy()
    bystander_logits = np.asarray(M.read_logits_page_fn(CFG, pool, i32(7)))

    c = 8
    m = CFG.spec_scratch_pages(c)
    scratch = i32(list(range(20, 20 + m)))
    pool = M.adopt_paged_fn(CFG, pool, prefill([2, 50, 60]),
                            seq_tables([5]), i32(9))
    pool = M.spec_chunk_paged_fn(CFG, spec_tokens([70, 8, 8], c), i32(3),
                                 i32(3), seq_tables([5]), scratch, pool, *ARRS)
    np.testing.assert_array_equal(np.asarray(pool)[:, :, 3], bystander_kv)
    np.testing.assert_array_equal(
        np.asarray(M.read_logits_page_fn(CFG, pool, i32(7))), bystander_logits)


def test_spec_chunk_c16_roundtrip():
    """C=16 exercises the packing's capacity edge (the whole plane-0
    region on dense; multiple scratch pages on paged)."""
    prompt = [1, 10, 20, 30]
    chunk = [40] + [3, 17] * 6            # 13 valid rows
    c = 16
    ref = tokenwise_rows(prompt, chunk)

    kv_one = prefill(prompt)
    kv_one = M.spec_chunk_fn(CFG, spec_tokens(chunk, c), i32(len(prompt)),
                             i32(len(chunk)), kv_one, *ARRS)
    dense = np.asarray(M.read_logits_chunk_fn(CFG, c, kv_one))

    m = CFG.spec_scratch_pages(c)
    assert m >= 2, m                      # qwen3-0.6b needs >1 page at C=16
    scratch = i32(list(range(15, 15 + m)))
    tables = seq_tables([4])
    pool = jnp.zeros(M.kv_pool_shape(CFG), jnp.float32)
    pool = M.adopt_paged_fn(CFG, pool, prefill(prompt), tables, i32(9))
    pool = M.spec_chunk_paged_fn(CFG, spec_tokens(chunk, c), i32(len(prompt)),
                                 i32(len(chunk)), tables, scratch, pool, *ARRS)
    paged = np.asarray(M.read_logits_chunk_paged_fn(CFG, c, pool, scratch))

    np.testing.assert_array_equal(paged, dense)
    np.testing.assert_allclose(dense[: len(chunk)], ref, atol=2e-4)
    np.testing.assert_array_equal(dense[: len(chunk)].argmax(-1),
                                  ref.argmax(-1))
