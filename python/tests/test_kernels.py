"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (batch, heads, GQA group, lengths, K/N sizes)
and asserts allclose against ref.py.  These tests gate everything above:
the AOT artifacts embed these kernels.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

# Property sweeps need hypothesis; CI installs it, but container images
# without it should still run the rest of the suite.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.patch_embed import patch_embed


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- attention

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 8]),
    hkv=st.sampled_from([1, 2, 3]),
    group=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    s=st.sampled_from([4, 16, 33, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, hkv, group, dh, s, seed):
    r = rng(seed)
    hq = hkv * group
    q = jnp.asarray(r.standard_normal((b, hq, dh)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, hkv, s, dh)), jnp.float32)
    lengths = jnp.asarray(r.integers(1, s + 1, size=b), jnp.int32)
    got = decode_attention(q, k, v, lengths)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_ignores_padding():
    """Garbage beyond `length` must not influence the output."""
    r = rng(0)
    q = jnp.asarray(r.standard_normal((2, 4, 16)), jnp.float32)
    k = jnp.asarray(r.standard_normal((2, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(r.standard_normal((2, 2, 32, 16)), jnp.float32)
    lengths = jnp.asarray([5, 17], jnp.int32)
    base = decode_attention(q, k, v, lengths)
    # Poison the padded tail.
    k2 = k.at[:, :, 20:, :].set(1e6)
    v2 = v.at[:, :, 20:, :].set(-1e6)
    k2 = k2.at[0, :, 5:, :].set(999.0)
    v2 = v2.at[0, :, 5:, :].set(-999.0)
    got = decode_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_decode_attention_length_one():
    """length==1 attends only to position 0 => output == v[:, :, 0]."""
    r = rng(1)
    q = jnp.asarray(r.standard_normal((1, 2, 8)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, 16, 8)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, 16, 8)), jnp.float32)
    lengths = jnp.asarray([1], jnp.int32)
    got = decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(got[0], v[0, :, 0, :], rtol=1e-6, atol=1e-6)


def test_decode_attention_gqa_head_mapping():
    """With group=2, query heads (0,1) must read KV head 0, (2,3) head 1."""
    r = rng(2)
    b, hkv, group, dh, s = 1, 2, 2, 8, 8
    q = jnp.asarray(r.standard_normal((b, hkv * group, dh)), jnp.float32)
    # Make KV heads wildly different.
    k = jnp.zeros((b, hkv, s, dh), jnp.float32)
    v = jnp.zeros((b, hkv, s, dh), jnp.float32)
    v = v.at[:, 0].set(1.0).at[:, 1].set(-1.0)
    lengths = jnp.asarray([s], jnp.int32)
    out = decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out[0, 0], jnp.ones(dh), atol=1e-6)
    np.testing.assert_allclose(out[0, 1], jnp.ones(dh), atol=1e-6)
    np.testing.assert_allclose(out[0, 2], -jnp.ones(dh), atol=1e-6)
    np.testing.assert_allclose(out[0, 3], -jnp.ones(dh), atol=1e-6)


# ------------------------------------------------------------- quant matmul

@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 2, 5, 16]),
    k=st.sampled_from([64, 128, 192]),
    n=st.sampled_from([32, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    w_packed, scales, group = ref.pack_weights_q4(jnp.asarray(w))
    got = quant_matmul(x, w_packed, scales, group, block_n=min(n, 128))
    want = ref.quant_matmul_ref(x, w_packed, scales, group)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_quant_roundtrip_error_bounded():
    """q4 quantization error must stay within the per-group scale bound."""
    r = rng(3)
    w = r.standard_normal((128, 64)).astype(np.float32)
    w_packed, scales, group = ref.pack_weights_q4(jnp.asarray(w))
    # Dequantize via the reference path with identity activations.
    eye = jnp.eye(128, dtype=jnp.float32)
    w_deq = np.asarray(ref.quant_matmul_ref(eye, w_packed, scales, group))
    err = np.abs(w_deq - w)
    bound = np.repeat(np.asarray(scales), group, axis=0) * 0.5 + 1e-6
    assert (err <= bound).all(), float(err.max())


def test_quant_matmul_blocked_equals_unblocked():
    r = rng(4)
    x = jnp.asarray(r.standard_normal((8, 128)), jnp.float32)
    w = jnp.asarray(r.standard_normal((128, 256)), jnp.float32)
    w_packed, scales, group = ref.pack_weights_q4(w)
    a = quant_matmul(x, w_packed, scales, group, block_m=8, block_n=256)
    b = quant_matmul(x, w_packed, scales, group, block_m=4, block_n=64)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- patch embed

@settings(max_examples=15, deadline=None)
@given(
    p=st.sampled_from([4, 16, 64, 196]),
    c=st.sampled_from([48, 192]),
    d=st.sampled_from([32, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_patch_embed_matches_ref(p, c, d, seed):
    r = rng(seed)
    patches = jnp.asarray(r.standard_normal((p, c)), jnp.float32)
    w = jnp.asarray(r.standard_normal((c, d)) * 0.05, jnp.float32)
    b = jnp.asarray(r.standard_normal(d), jnp.float32)
    bp = 4 if p % 4 == 0 else 1
    got = patch_embed(patches, w, b, block_p=bp)
    want = ref.patch_embed_ref(patches, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_patch_embed_bias_only():
    p, c, d = 8, 12, 16
    patches = jnp.zeros((p, c), jnp.float32)
    w = jnp.ones((c, d), jnp.float32)
    b = jnp.arange(d, dtype=jnp.float32)
    got = patch_embed(patches, w, b, block_p=8)
    np.testing.assert_allclose(got, jnp.tile(b, (p, 1)), atol=1e-6)


# ------------------------------------------------- kernels inside jax.jit

def test_kernels_jit_and_lower():
    """The kernels must lower inside jax.jit (the AOT path depends on it)."""
    r = rng(5)
    q = jnp.asarray(r.standard_normal((2, 4, 16)), jnp.float32)
    k = jnp.asarray(r.standard_normal((2, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(r.standard_normal((2, 2, 32, 16)), jnp.float32)
    lengths = jnp.asarray([10, 20], jnp.int32)

    @jax.jit
    def f(q, k, v, lengths):
        return decode_attention(q, k, v, lengths)

    np.testing.assert_allclose(
        f(q, k, v, lengths), ref.decode_attention_ref(q, k, v, lengths),
        rtol=2e-5, atol=2e-5,
    )
    # And the lowering produces HLO text (the artifact format).
    hlo = jax.jit(f).lower(q, k, v, lengths).compiler_ir("stablehlo")
    assert "stablehlo" in str(hlo) or "module" in str(hlo)
