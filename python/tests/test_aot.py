"""AOT manifest + artifact integrity (requires `make artifacts`)."""

import json
import os

import pytest

from compile.configs import (
    EMBED_PREFILL_BUCKETS,
    KV_PAGE_SIZE,
    MODELS,
    PREFILL_CHUNK_BUCKETS,
    SPEC_CHUNK_BUCKETS,
    VISION_BATCH_BUCKETS,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_all_models_present(manifest):
    assert set(manifest["models"]) == set(MODELS)


@pytest.mark.parametrize("name", list(MODELS))
def test_entry_inventory(manifest, name):
    """Serving is paged-only: every lowered text entry operates on the
    page pool over block tables; the dense single-arena graphs are
    python-level references and must NOT appear in the artifacts."""
    cfg = MODELS[name]
    m = manifest["models"][name]
    entries = m["entries"]
    for b in cfg.decode_buckets:
        assert f"decode_paged_b{b}" in entries, f"{name} missing decode_paged_b{b}"
    for c in PREFILL_CHUNK_BUCKETS:
        assert f"prefill_chunk_paged_c{c}" in entries
    for c in SPEC_CHUNK_BUCKETS:
        assert f"spec_chunk_paged_c{c}" in entries
        assert f"read_logits_chunk_paged_c{c}" in entries
    for entry in ("copy_page", "zeros_pool", "read_logits_page"):
        assert entry in entries, f"{name} missing {entry}"
    assert m["prefill_chunk_buckets"] == list(PREFILL_CHUNK_BUCKETS)
    assert m["spec_chunk_buckets"] == list(SPEC_CHUNK_BUCKETS)
    assert m["kv_page_size"] == KV_PAGE_SIZE
    assert m["kv_pool_pages"] == cfg.kv_pool_pages()
    assert m["decode_virtual_lanes"] == cfg.decode_virtual_lanes()
    # No dense-era entries: retired grids must not be re-lowered.
    for entry in entries:
        for stale in ("decode_b", "inject_b", "extract_b", "zeros_b",
                      "read_logits_b", "read_logits_one_b", "prefill_s",
                      "prefill_embeds_s", "adopt_paged"):
            assert not entry.startswith(stale), f"{name} re-lowered {entry}"
        assert "trim" not in entry, f"{name} re-lowered {entry}"
        if entry.startswith("prefill_chunk"):
            assert "paged" in entry, f"{name} re-lowered dense {entry}"
        if entry.startswith(("spec_chunk", "read_logits_chunk")):
            assert "paged" in entry, f"{name} re-lowered dense {entry}"
    assert "trim_kv_buckets" not in m
    if cfg.vision:
        for r in cfg.vision.resolutions:
            assert f"vision_r{r}" in entries
            for b in VISION_BATCH_BUCKETS:
                assert f"vision_r{r}_b{b}" in entries, f"{name} missing vision_r{r}_b{b}"
        assert m["vision"]["batch_buckets"] == list(VISION_BATCH_BUCKETS)
        for s in EMBED_PREFILL_BUCKETS:
            assert f"embed_lookup_s{s}" in entries
        for c in PREFILL_CHUNK_BUCKETS:
            assert f"prefill_chunk_embeds_paged_c{c}" in entries


@pytest.mark.parametrize("name", list(MODELS))
def test_artifact_files_exist_and_are_hlo(manifest, name):
    m = manifest["models"][name]
    assert os.path.exists(os.path.join(ART, m["weights_file"]))
    for entry, desc in m["entries"].items():
        path = os.path.join(ART, desc["file"])
        assert os.path.exists(path), f"{name}/{entry} artifact missing"
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name}/{entry} is not HLO text"


def test_arg_descriptors_sane(manifest):
    m = manifest["models"]["qwen3-0.6b"]
    d = m["entries"]["decode_paged_b1"]["args"]
    kinds = [a["kind"] for a in d]
    # All inputs precede all weights.
    first_weight = kinds.index("weight")
    assert all(k == "weight" for k in kinds[first_weight:])
    assert [a["name"] for a in d[:5]] == ["tokens", "pos", "tables", "mailbox", "pool"]
    pool = d[4]
    nblk = m["s_max"] // m["kv_page_size"]
    assert d[2]["shape"] == [1, nblk]
    assert pool["shape"] == [
        m["n_layers"] + 1, 2, m["kv_pool_pages"], m["n_kv_heads"],
        m["kv_page_size"], m["d_head"],
    ]
    # Weight order starts with the embedding table.
    assert d[5]["name"] == "emb"


def test_mailbox_fits_every_model(manifest):
    for name, m in manifest["models"].items():
        # One mailbox page (plane 0, k side) must cover the vocab.
        assert m["n_kv_heads"] * m["kv_page_size"] * m["d_head"] >= m["vocab"], (
            f"{name}: logits mailbox would overflow one page"
        )
