"""AOT manifest + artifact integrity (requires `make artifacts`)."""

import json
import os

import pytest

from compile.configs import (
    EMBED_PREFILL_BUCKETS,
    MODELS,
    PREFILL_CHUNK_BUCKETS,
    VISION_BATCH_BUCKETS,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_all_models_present(manifest):
    assert set(manifest["models"]) == set(MODELS)


@pytest.mark.parametrize("name", list(MODELS))
def test_entry_inventory(manifest, name):
    cfg = MODELS[name]
    entries = manifest["models"][name]["entries"]
    for b in cfg.decode_buckets:
        for kind in ("decode", "inject", "extract", "read_logits",
                     "read_logits_one", "zeros"):
            assert f"{kind}_b{b}" in entries, f"{name} missing {kind}_b{b}"
    for s in cfg.prefill_buckets:
        assert f"prefill_s{s}" in entries
    for c in PREFILL_CHUNK_BUCKETS:
        assert f"prefill_chunk_c{c}" in entries
    assert manifest["models"][name]["prefill_chunk_buckets"] == list(
        PREFILL_CHUNK_BUCKETS)
    # Every model lowers the cached-KV trim grids (text prefix cache and
    # mm KV cache both trim their entries at insert).
    for s in cfg.trim_kv_buckets():
        assert f"trim_kv_s{s}" in entries, f"{name} missing trim_kv_s{s}"
        assert f"untrim_kv_s{s}" in entries
    assert manifest["models"][name]["trim_kv_buckets"] == list(cfg.trim_kv_buckets())
    if cfg.vision:
        for r in cfg.vision.resolutions:
            assert f"vision_r{r}" in entries
            for b in VISION_BATCH_BUCKETS:
                assert f"vision_r{r}_b{b}" in entries, f"{name} missing vision_r{r}_b{b}"
        assert manifest["models"][name]["vision"]["batch_buckets"] == list(
            VISION_BATCH_BUCKETS)
        for s in EMBED_PREFILL_BUCKETS:
            assert f"prefill_embeds_s{s}" in entries
            assert f"embed_lookup_s{s}" in entries
        for c in PREFILL_CHUNK_BUCKETS:
            assert f"prefill_chunk_embeds_c{c}" in entries


@pytest.mark.parametrize("name", list(MODELS))
def test_artifact_files_exist_and_are_hlo(manifest, name):
    m = manifest["models"][name]
    assert os.path.exists(os.path.join(ART, m["weights_file"]))
    for entry, desc in m["entries"].items():
        path = os.path.join(ART, desc["file"])
        assert os.path.exists(path), f"{name}/{entry} artifact missing"
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name}/{entry} is not HLO text"


def test_arg_descriptors_sane(manifest):
    m = manifest["models"]["qwen3-0.6b"]
    d = m["entries"]["decode_b1"]["args"]
    kinds = [a["kind"] for a in d]
    # All inputs precede all weights.
    first_weight = kinds.index("weight")
    assert all(k == "weight" for k in kinds[first_weight:])
    assert [a["name"] for a in d[:3]] == ["tokens", "pos", "kv"]
    kv = d[2]
    assert kv["shape"] == [m["n_layers"] + 1, 2, 1, m["n_kv_heads"], m["s_max"], m["d_head"]]
    # Weight order starts with the embedding table.
    assert d[3]["name"] == "emb"


def test_mailbox_fits_every_model(manifest):
    for name, m in manifest["models"].items():
        rows = -(-m["vocab"] // m["d_head"])
        assert rows <= m["s_max"], f"{name}: logits mailbox would overflow the arena"
